"""`python -m repro.api` CLI: prune → report → finetune round-trips,
JSON event streaming, and the structured serve-unsupported path.

Runs the CLI in-process (``cli.main(argv)``) — same code path as
``python -m repro.api`` without interpreter startup per case.
"""
import json

import pytest

from repro.api import cli


def _run(capsys, argv):
    code = cli.main(argv)
    return code, capsys.readouterr().out


def _json_lines(out):
    return [json.loads(line) for line in out.splitlines() if line.strip()]


def test_archs_lists_every_registered_name(capsys):
    from repro.api import list_adaptable
    code, out = _run(capsys, ["archs", "--json"])
    assert code == 0
    rows = _json_lines(out)
    assert {r["arch"] for r in rows} == set(list_adaptable())
    by_arch = {r["arch"]: r for r in rows}
    assert by_arch["vgg11"]["family"] == "cnn"
    assert by_arch["deepseek-v3-671b"]["granularities"][0] == "expert"
    # the audio family serves through the engine's frames lane (PR 6)
    assert by_arch["whisper-tiny"]["serves"] is True
    assert by_arch["vgg11"]["serves"] is False


def test_cnn_prune_report_finetune_roundtrip(tmp_path, capsys):
    ticket = str(tmp_path / "ticket")
    code, out = _run(capsys, [
        "prune", "--arch", "vgg11", "--scale", "tiny", "--rounds", "1",
        "--tolerance", "1e9", "--steps", "2", "--ticket", ticket, "--json"])
    assert code == 0
    events = _json_lines(out)
    rounds = [e for e in events if e["event"] == "round"]
    result = [e for e in events if e["event"] == "result"]
    assert len(rounds) == 1 and len(result) == 1
    assert rounds[0]["granularity"] == "filter"
    assert rounds[0]["accepted"] is True
    assert 0.1 < rounds[0]["sparsity_after"] < 0.5
    assert "live_tile_fraction" in rounds[0]
    assert result[0]["ticket"] == ticket
    assert result[0]["xbars_needed"] <= result[0]["xbars_unpruned"]

    code, out = _run(capsys, ["report", "--arch", "vgg11",
                              "--ticket", ticket, "--json"])
    assert code == 0
    rep = _json_lines(out)[0]
    assert rep["event"] == "report"
    assert rep["mask_sparsity"] == pytest.approx(
        result[0]["sparsity"], abs=1e-6)
    assert rep["xbar_rows"] == 128

    code, out = _run(capsys, ["finetune", "--arch", "vgg11",
                              "--ticket", ticket, "--steps", "2", "--json"])
    assert code == 0
    ft = _json_lines(out)[0]
    assert ft["event"] == "finetune"
    assert ft["loss"] is not None


@pytest.mark.slow
def test_lm_prune_finetune_serve_roundtrip(tmp_path, capsys):
    ticket = str(tmp_path / "lm_ticket")
    code, out = _run(capsys, [
        "prune", "--arch", "llama3.2-3b", "--scale", "tiny", "--rounds",
        "1", "--tolerance", "1e9", "--steps", "2", "--ticket", ticket,
        "--json"])
    assert code == 0
    events = _json_lines(out)
    assert events[-1]["event"] == "result"
    assert events[0]["accuracy"] < 0                # -CE score

    code, out = _run(capsys, ["finetune", "--arch", "llama3.2-3b",
                              "--ticket", ticket, "--steps", "2", "--json"])
    assert code == 0
    assert _json_lines(out)[0]["event"] == "finetune"

    code, out = _run(capsys, [
        "serve", "--arch", "llama3.2-3b", "--scale", "tiny",
        "--ticket", ticket, "--requests", "2", "--max-new", "3", "--json"])
    assert code == 0
    rep = _json_lines(out)[0]
    assert rep["event"] == "serve"
    assert rep["requests"] == 2
    assert rep["tokens"] > 0
    assert rep["bsmm"] is True                      # ticket masks rode along


def test_serve_unsupported_family_reports_not_raises(tmp_path, capsys):
    code, out = _run(capsys, ["serve", "--arch", "vgg11", "--json"])
    assert code == cli.EXIT_UNSUPPORTED
    rep = _json_lines(out)[0]
    assert rep["event"] == "serve_unsupported"
    assert rep["family"] == "cnn"
    assert rep["reason"]


def test_serve_audio_family_through_frames_lane(capsys):
    """whisper serves now: requests carry synthetic encoder frames and
    the report includes the latency percentiles."""
    code, out = _run(capsys, ["serve", "--arch", "whisper-tiny",
                              "--requests", "2", "--max-new", "3",
                              "--capacity", "32", "--json"])
    assert code == 0
    rep = _json_lines(out)[0]
    assert rep["event"] == "serve"
    assert rep["requests"] == 2 and rep["tokens"] == 6
    assert rep["ttft_p50_ms"] > 0 and rep["tps_p50"] > 0
    assert rep["deadline_misses"] == 0


def test_ticket_scale_mismatch_reports_not_tracebacks(tmp_path, capsys):
    """A ticket pruned for one shape must not crash deep inside the
    model when loaded at another — structured error, exit 2."""
    ticket = str(tmp_path / "t")
    code, _ = _run(capsys, [
        "prune", "--arch", "vgg11", "--scale", "tiny", "--rounds", "1",
        "--tolerance", "1e9", "--steps", "2", "--ticket", ticket, "--json"])
    assert code == 0
    code, out = _run(capsys, ["report", "--arch", "resnet18",
                              "--ticket", ticket, "--json"])
    assert code == cli.EXIT_UNSUPPORTED
    rep = _json_lines(out)[0]
    assert rep["event"] == "ticket_mismatch"
    assert "scale" in rep["reason"] or "arch" in rep["reason"]


def test_prune_granularity_override(capsys):
    code, out = _run(capsys, [
        "prune", "--arch", "vgg11", "--scale", "tiny", "--rounds", "1",
        "--tolerance", "1e9", "--steps", "2", "--granularity",
        "index", "--json"])
    assert code == 0
    rounds = [e for e in _json_lines(out) if e["event"] == "round"]
    assert rounds[0]["granularity"] == "index"


def test_recipes_subcommand_lists_builtins_and_tuned(capsys):
    code, out = _run(capsys, ["recipes", "--json"])
    assert code == 0
    rows = {r["recipe"]: r for r in _json_lines(out)}
    assert {"paper", "paper-quant", "paper-xbar", "ablation",
            "cnn-full", "dense-full", "moe-full"} <= set(rows)
    assert rows["paper"]["stages"] == ["prune:filter", "prune:channel",
                                       "prune:index"]
    assert rows["cnn-full"]["families"] == ["cnn"]
    assert "quantize:int8" in rows["moe-full"]["stages"]


def test_prune_with_recipe_streams_stage_events(tmp_path, capsys):
    """`prune --recipe` runs a multi-stage program (incl. a quantize
    stage); every --json event carries stage name/index, the ticket
    embeds the recipe, and report/finetune pick the metadata up."""
    ticket = str(tmp_path / "rt")
    code, out = _run(capsys, [
        "prune", "--arch", "scaled_down_cnn", "--recipe", "paper-quant",
        "--rounds", "1", "--tolerance", "1e9", "--steps", "2",
        "--ticket", ticket, "--json"])
    assert code == 0
    events = _json_lines(out)
    rounds = [e for e in events if e["event"] == "round"]
    assert all("stage" in e and "stage_idx" in e and "kind" in e
               for e in rounds)
    assert rounds[0]["stage"] == "prune:filter"
    assert rounds[-1]["kind"] == "quantize"
    result = events[-1]
    assert result["recipe"] == "paper-quant"
    assert result["quantize_bits"] == 8
    assert result["weight_bytes"]["quantized_bytes"] is not None
    assert (result["weight_bytes"]["quantized_bytes"]
            < result["weight_bytes"]["pruned_bytes"])

    code, out = _run(capsys, ["report", "--arch", "scaled_down_cnn",
                              "--ticket", ticket, "--json"])
    assert code == 0
    rep = _json_lines(out)[0]
    assert rep["recipe"] == "paper-quant"
    assert rep["quantize_bits"] == 8
    assert rep["weight_bytes"]["quantized_bytes"] is not None

    code, out = _run(capsys, ["finetune", "--arch", "scaled_down_cnn",
                              "--ticket", ticket, "--steps", "2",
                              "--json"])
    assert code == 0
    ft = _json_lines(out)[0]
    assert ft["quantize_bits"] == 8          # QAT fine-tune


def test_prune_with_recipe_file(tmp_path, capsys):
    from repro.api.recipes import Recipe, prune_stage

    path = str(tmp_path / "custom.json")
    Recipe(name="custom", stages=(prune_stage("xbar", rate=0.3),)
           ).save(path)
    code, out = _run(capsys, [
        "prune", "--arch", "scaled_down_cnn", "--recipe", path,
        "--rounds", "1", "--tolerance", "1e9", "--steps", "2", "--json"])
    assert code == 0
    events = _json_lines(out)
    assert events[0]["granularity"] == "xbar"
    assert events[-1]["recipe"] == "custom"
