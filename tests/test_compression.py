"""Gradient compression: error feedback, mask-awareness, sparse psum."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (MaskAwareCompressor,
                                           TopKCompressor)


def test_topk_keeps_largest_and_tracks_residual():
    comp = TopKCompressor(k_fraction=0.25)
    g = {"w": jnp.asarray(np.array([[4.0, -3.0, 0.1, 0.2],
                                    [0.3, 0.1, -5.0, 0.05]]))}
    res = comp.init(g)
    sparse, res, stats = comp.compress(g, res)
    s = np.asarray(sparse["w"])
    assert s[0, 0] == 4.0 and s[1, 2] == -5.0
    assert (s != 0).sum() == 2
    # residual holds what was dropped
    np.testing.assert_allclose(np.asarray(res["w"]) + s,
                               np.asarray(g["w"]), atol=1e-6)
    assert stats["sent_fraction"] == pytest.approx(0.25)


def test_error_feedback_conserves_signal():
    """Σ_t compressed_t + final residual == Σ_t grads (nothing lost)."""
    comp = TopKCompressor(k_fraction=0.1)
    rng = np.random.RandomState(0)
    g_total = np.zeros((8, 8))
    sent_total = np.zeros((8, 8))
    res = comp.init({"w": jnp.zeros((8, 8))})
    for t in range(20):
        g = rng.randn(8, 8)
        g_total += g
        sparse, res, _ = comp.compress({"w": jnp.asarray(g)}, res)
        sent_total += np.asarray(sparse["w"])
    np.testing.assert_allclose(sent_total + np.asarray(res["w"]), g_total,
                               atol=1e-4)


def test_mask_aware_counts_only_survivors():
    m = np.zeros((10, 10), np.float32)
    m[:2] = 1.0                      # 20% survive
    comp = MaskAwareCompressor(masks={"w": jnp.asarray(m)})
    g = {"w": jnp.asarray(np.random.RandomState(1).randn(10, 10))}
    res = comp.init(g)
    sparse, res, stats = comp.compress(g, res)
    assert stats["sent_fraction"] == pytest.approx(0.2)
    # pruned coordinates transmitted as exact zeros
    assert (np.asarray(sparse["w"])[2:] == 0).all()


def test_mask_aware_with_topk_compounds():
    m = np.zeros((10, 10), np.float32)
    m[:5] = 1.0
    comp = MaskAwareCompressor(masks={"w": jnp.asarray(m)},
                               k_fraction=0.2)
    g = {"w": jnp.asarray(np.random.RandomState(2).randn(10, 10))}
    sparse, _, stats = comp.compress(g, comp.init(g))
    assert stats["sent_fraction"] == pytest.approx(0.5 * 0.2, abs=0.02)


def test_compressed_train_step_end_to_end():
    """TopK-compressed training still converges (error feedback works)."""
    import jax
    from repro.optim import adamw, constant
    from repro.train.loop import init_opt_state, make_train_step

    params = {"w": jnp.zeros((8, 8))}

    def loss_fn(p, batch):
        return jnp.sum((p["w"] - batch["target"]) ** 2), {}

    comp = TopKCompressor(k_fraction=0.1)
    opt = adamw(constant(0.05))
    step = make_train_step(loss_fn, opt, donate=False, compressor=comp)
    state = init_opt_state(opt, params, comp)
    batch = {"target": jnp.ones((8, 8)) * 2.0}
    for _ in range(450):
        params, state, metrics = step(params, state, batch)
    assert float(metrics["loss"]) < 0.05
    assert float(metrics["sent_fraction"]) == pytest.approx(0.094, abs=0.05)
