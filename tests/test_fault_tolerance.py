"""Fault tolerance: supervised restart, heartbeats, straggler policy,
elastic (cross-mesh) restore path."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataPipeline, SyntheticLM
from repro.distributed.fault_tolerance import (HeartbeatMonitor, SkipStraggler,
                                               Supervisor)
from repro.optim import adamw, constant
from repro.train import Trainer


def _tiny():
    import jax.random as jr
    ks = jr.split(jr.PRNGKey(0), 2)
    params = {"w": jr.normal(ks[0], (16, 16)) * 0.1}
    gen = SyntheticLM(vocab_size=16, seq_len=8, seed=0)

    def loss_fn(p, batch):
        x = jax.nn.one_hot(batch["tokens"], 16)
        logits = x @ p["w"]
        ll = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(
            ll, batch["labels"][..., None], -1).mean(), {}

    def batch_fn(s):
        return {k: jnp.asarray(v) for k, v in gen.batch(s, 4).items()}

    return params, loss_fn, batch_fn


def test_supervisor_restarts_through_failures(tmp_path):
    params, loss_fn, batch_fn = _tiny()
    crashes = {"left": 2}

    def make_trainer():
        pipe = DataPipeline(batch_fn, prefetch=0)
        t = Trainer(loss_fn=loss_fn, optimizer=adamw(constant(1e-2)),
                    params=params, data_iter=pipe, ckpt_dir=str(tmp_path),
                    ckpt_every=2, async_ckpt=False)
        orig = t.step_fn

        def flaky(p, o, b):
            # crash mid-training twice (after resuming past step 4)
            if crashes["left"] > 0 and t.state.step == 5:
                crashes["left"] -= 1
                raise RuntimeError("injected node failure")
            return orig(p, o, b)

        t.step_fn = flaky
        return t

    sup = Supervisor(make_trainer=make_trainer, max_restarts=5)
    trainer = sup.run(10)
    assert trainer.state.step == 10
    assert crashes["left"] == 0          # both injected failures happened


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    params, loss_fn, batch_fn = _tiny()

    def make_trainer():
        pipe = DataPipeline(batch_fn, prefetch=0)
        t = Trainer(loss_fn=loss_fn, optimizer=adamw(constant(1e-2)),
                    params=params, data_iter=pipe, ckpt_dir=str(tmp_path),
                    ckpt_every=100, async_ckpt=False)

        def always_fail(p, o, b):
            raise RuntimeError("permanent failure")

        t.step_fn = always_fail
        return t

    sup = Supervisor(make_trainer=make_trainer, max_restarts=2)
    with pytest.raises(RuntimeError, match="permanent"):
        sup.run(10)


def test_heartbeat_monitor(tmp_path):
    mon = HeartbeatMonitor(str(tmp_path), deadline_s=0.2)
    mon.beat("worker0")
    mon.beat("worker1")
    assert mon.dead_workers() == []
    time.sleep(0.3)
    mon.beat("worker1")
    assert mon.dead_workers() == ["worker0"]


def test_heartbeat_monitor_injectable_clock(tmp_path):
    """No real sleeps: dead/revived transitions driven by a fake clock."""
    t = [0.0]
    mon = HeartbeatMonitor(str(tmp_path), deadline_s=5.0,
                           clock=lambda: t[0])
    assert mon.age("w0") is None         # never beat
    mon.beat("w0")
    mon.beat("w1")
    t[0] = 4.0
    assert mon.age("w0") == 4.0
    assert mon.dead_workers() == []
    t[0] = 6.0
    mon.beat("w1")
    assert mon.dead_workers() == ["w0"]
    t[0] = 7.0                            # w0's beats resume (flap)
    mon.beat("w0")
    assert mon.dead_workers() == []
    assert mon.age("w0") == 0.0


def test_skip_straggler_escalates():
    escalations = []
    pol = SkipStraggler(deadline_s=1.0, budget=2, window=100,
                        escalate=escalations.append)
    for step in (1, 2, 3):
        pol(step, 5.0)
    assert escalations == [3]          # budget 2 exceeded on 3rd event
    pol(50, 5.0)                        # window reset after escalation
    assert escalations == [3]


def test_elastic_restore_same_values(tmp_path):
    """Checkpoint saved with one layout restores onto a fresh template
    (the cross-mesh path: leaves are full arrays, re-placed per rules)."""
    params = {"params": {"w": jnp.arange(64.0).reshape(8, 8)},
              "opt_state": {"mu": {"w": jnp.zeros((8, 8))},
                            "step": jnp.asarray(3, jnp.int32)},
              "step": jnp.asarray(7, jnp.int32)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, params)
    template = jax.tree.map(jnp.zeros_like, params)
    step, got = mgr.restore(template)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.arange(64.0).reshape(8, 8))
