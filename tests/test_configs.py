"""Exact assigned configs: dimensions and parameter-count sanity."""
import pytest

from repro.configs import get_arch, get_cnn, list_archs, list_cnns

# (name, layers, d_model, heads, kv, d_ff, vocab)
ASSIGNED = [
    ("recurrentgemma-2b", 26, 2560, 10, 1, 7680, 256000),
    ("phi-3-vision-4.2b", 32, 3072, 32, 32, 8192, 32064),
    ("yi-6b", 32, 4096, 32, 4, 11008, 64000),
    ("command-r-35b", 40, 8192, 64, 8, 22528, 256000),
    ("llama3.2-3b", 28, 3072, 24, 8, 8192, 128256),
    ("qwen2-72b", 80, 8192, 64, 8, 29568, 152064),
    ("deepseek-v3-671b", 61, 7168, 128, 128, 2048, 129280),
    ("llama4-maverick-400b-a17b", 48, 5120, 40, 8, 8192, 202048),
    ("whisper-tiny", 4, 384, 6, 6, 1536, 51865),
    ("xlstm-125m", 12, 768, 4, 4, 0, 50304),
]


@pytest.mark.parametrize("name,L,d,H,kv,ff,v", ASSIGNED)
def test_assigned_dims_exact(name, L, d, H, kv, ff, v):
    cfg = get_arch(name)
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == kv
    assert cfg.vocab_size == v
    if name == "deepseek-v3-671b":
        assert cfg.moe is not None and cfg.moe.d_ff_expert == ff
        assert cfg.moe.num_experts == 256 and cfg.moe.top_k == 8
        assert cfg.moe.num_shared_experts == 1
        assert cfg.mla is not None
    else:
        assert cfg.d_ff == ff
    if name == "llama4-maverick-400b-a17b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 1


PARAM_BOUNDS = {
    "recurrentgemma-2b": (2.0e9, 3.3e9),
    "phi-3-vision-4.2b": (3.3e9, 4.6e9),
    "yi-6b": (5.4e9, 6.7e9),
    "command-r-35b": (27e9, 38e9),
    "llama3.2-3b": (2.8e9, 3.8e9),
    "qwen2-72b": (65e9, 80e9),
    "deepseek-v3-671b": (600e9, 740e9),
    "llama4-maverick-400b-a17b": (360e9, 440e9),
    "whisper-tiny": (20e6, 80e6),
    "xlstm-125m": (90e6, 260e6),
}


@pytest.mark.parametrize("name", sorted(PARAM_BOUNDS))
def test_param_counts_in_published_range(name):
    lo, hi = PARAM_BOUNDS[name]
    n = get_arch(name).param_count()
    assert lo <= n <= hi, f"{name}: {n / 1e9:.2f}B not in [{lo}, {hi}]"


def test_moe_active_params():
    ds = get_arch("deepseek-v3-671b")
    assert 30e9 < ds.active_param_count() < 45e9        # ~37B active
    l4 = get_arch("llama4-maverick-400b-a17b")
    assert 12e9 < l4.active_param_count() < 20e9        # ~17B active


def test_cnn_configs():
    # scaled_down_cnn: the registered tiny smoke CNN (vgg11 structure,
    # capped channels) CI addresses by name
    assert set(list_cnns()) == {"vgg11", "vgg16", "vgg19", "resnet18",
                                "scaled_down_cnn"}
    r18 = get_cnn("resnet18")
    assert len(r18.convs) == 17                          # C1-C17 (Fig. 8)
    n = r18.param_count()
    assert 10e6 < n < 12e6
    assert len(get_cnn("vgg19").convs) == 16


def test_padded_vocab_divisible():
    for a in list_archs():
        cfg = get_arch(a)
        assert cfg.padded_vocab % 2048 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
