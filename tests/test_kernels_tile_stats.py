"""tile_stats Pallas kernel vs oracle over shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import tile_stats
from repro.kernels.ref import tile_stats_ref
from repro.kernels.tile_stats import tile_stats_pallas


@pytest.mark.parametrize("K,N", [(128, 128), (256, 384), (512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tile_stats_matches_oracle(K, N, dtype):
    rng = np.random.RandomState(K + N)
    w = rng.randn(K, N).astype(np.float32)
    w[: K // 2, : N // 2] = 0.0          # a dead tile quadrant
    wj = jnp.asarray(w, dtype)
    live, sums = tile_stats_pallas(wj, interpret=True)
    live_r, sums_r = tile_stats_ref(wj)
    np.testing.assert_array_equal(np.asarray(live, bool),
                                  np.asarray(live_r))
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_r),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_ragged_edges_padded():
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(200, 300), jnp.float32)
    live, sums = tile_stats(w)            # ops wrapper pads to 256×384
    assert live.shape == (2, 3)
    live_r, sums_r = tile_stats_ref(w)
    np.testing.assert_array_equal(np.asarray(live, bool),
                                  np.asarray(live_r))


def test_all_zero_matrix():
    w = jnp.zeros((256, 256), jnp.float32)
    live, sums = tile_stats(w)
    assert not np.asarray(live, bool).any()
    assert np.asarray(sums).sum() == 0.0
