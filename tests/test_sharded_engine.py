"""Sharded ServeEngine oracle: mesh-backed serving is bit-exact.

Two layers, matching ``tests/test_sharding.py``'s split:

* a subprocess run that forces 8 host-platform devices (XLA_FLAGS must
  be set before jax imports, so it cannot run in-process) and checks
  greedy outputs on (1,2) and (2,1) meshes against the single-device
  engine — dense AND pruned-ticket generations, dense AND paged KV;
* in-process tests that only run when the interpreter already has >1
  device (CI's virtual-device job exports
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and are
  skipped on the default single-device run.
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (CI virtual-device job forces 8)")


ORACLE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax
    from repro.analysis import audit_engine_sharding
    from repro.api import structured_prune
    from repro.api.registry import make_adapter
    from repro.configs import PruneConfig
    from repro.core.masks import lm_prunable
    from repro.launch.mesh import make_test_mesh
    from repro.models import transformer as tfm
    from repro.serve.engine import Request, ServeEngine

    ad = make_adapter("llama3.2-3b", scale="tiny")
    cfg = ad.cfg
    params = ad.init_params(jax.random.PRNGKey(0))
    masks = structured_prune(params, [("filter", 0.2)],
                             prunable=lm_prunable, cfg=PruneConfig())
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, cfg.vocab_size,
                           size=rng.randint(4, 14)).astype(np.int32)
               for _ in range(3)]

    def run(mesh, paged, m):
        eng = ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                          decode_fn=tfm.decode_step, batch_slots=2,
                          capacity=48, paged=paged, masks=m, mesh=mesh)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
        out = {r.uid: r.tokens for r in eng.run()}
        return eng, out

    for paged in (False, True):
        for m in (None, masks):
            tag = f"paged={paged} masks={m is not None}"
            _, base = run(None, paged, m)
            assert len(base) == len(prompts), tag
            for dxm in ((1, 2), (2, 1)):
                eng, got = run(make_test_mesh(*dxm), paged, m)
                assert got == base, (tag, dxm, got, base)
                finds = audit_engine_sharding(eng)
                assert not [f for f in finds if f.severity == "error"], \\
                    (tag, dxm, finds)
                if dxm == (1, 2):   # model axis live: params partitioned
                    assert finds == [], (tag, dxm, finds)
            print("OK", tag)
    print("SHARDED_ENGINE_OK")
""")


def test_sharded_engine_oracle_subprocess():
    """(1,2) and (2,1) meshes reproduce the single-device engine's
    greedy streams bit-exactly, dense + pruned, dense + paged KV."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", ORACLE_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=600)
    assert "SHARDED_ENGINE_OK" in out.stdout, \
        out.stdout[-2000:] + out.stderr[-2000:]


# ---------------------------------------------------------------------------
# in-process (CI virtual-device job)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    import numpy as np

    from repro.api.registry import make_adapter

    ad = make_adapter("llama3.2-3b", scale="tiny")
    params = ad.init_params(jax.random.PRNGKey(0))
    prompt = np.arange(1, 9, dtype=np.int32)
    return ad.cfg, params, prompt


def _engine(cfg, params, mesh=None, **kw):
    from repro.models import transformer as tfm
    from repro.serve.engine import ServeEngine

    return ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                       decode_fn=tfm.decode_step, batch_slots=2,
                       capacity=48, mesh=mesh, **kw)


@multi_device
@pytest.mark.parametrize("dxm", [(1, 2), (2, 1)])
def test_mesh_engine_matches_single_device(setup, dxm):
    from repro.launch.mesh import make_test_mesh

    cfg, params, prompt = setup
    want = _engine(cfg, params).smoke_decode(prompt, 6)
    got = _engine(cfg, params,
                  mesh=make_test_mesh(*dxm)).smoke_decode(prompt, 6)
    assert got == want


@multi_device
def test_mesh_engine_params_carry_named_shardings(setup):
    from jax.sharding import NamedSharding

    from repro.launch.mesh import make_test_mesh

    cfg, params, prompt = setup
    eng = _engine(cfg, params, mesh=make_test_mesh(1, 2))
    leaves = [l for l in jax.tree.leaves(eng.generations[-1].params)
              if hasattr(l, "sharding")]
    assert leaves and all(isinstance(l.sharding, NamedSharding)
                          for l in leaves)
    assert any(any(s is not None for s in l.sharding.spec)
               for l in leaves), "model axis should partition params"


@multi_device
def test_two_meshes_coexist_in_one_process(setup):
    """Scoped constrainer install: engines on different meshes in one
    process must not poison each other's traces."""
    from repro.launch.mesh import make_test_mesh

    cfg, params, prompt = setup
    want = _engine(cfg, params).smoke_decode(prompt, 6)
    a = _engine(cfg, params, mesh=make_test_mesh(1, 2))
    b = _engine(cfg, params, mesh=make_test_mesh(2, 1))
    assert a.smoke_decode(prompt, 6) == want
    assert b.smoke_decode(prompt, 6) == want
    assert a.smoke_decode(prompt, 6) == want   # a again, after b traced


def test_head_boundary_guard_in_param_spec():
    """Regression for the (2,4)-mesh wk bug: a column-parallel attention
    projection must never shard below head_dim granularity."""
    from repro.distributed.sharding import ShardingRules

    class FakeMesh:
        def __init__(self, shape):
            self.axis_names = tuple(shape)
            self.shape = dict(shape)

    r = ShardingRules(FakeMesh({"data": 2, "model": 4}), head_dim=32)
    # wk (d_model=128, n_kv=2·32=64): 64/4 = 16 < head_dim — the head
    # dim must stay whole, so sharding falls back to the in-dim
    assert tuple(r.param_spec("segments/0/0/attn/wk", (128, 64))) \
        == ("model", None)
    # wq (128, 128): 128/4 = 32 = head_dim — sharding is safe
    assert tuple(r.param_spec("segments/0/0/attn/wq", (128, 128))) \
        == (None, "model")
    # wo row-parallel gets the same guard on its (head-shaped) in-dim
    assert tuple(r.param_spec("segments/0/0/attn/wo", (64, 128))) \
        == (None, "model")
    # without head_dim metadata the old (unguarded) behaviour remains
    r2 = ShardingRules(FakeMesh({"data": 2, "model": 4}))
    assert tuple(r2.param_spec("segments/0/0/attn/wk", (128, 64))) \
        == (None, "model")
