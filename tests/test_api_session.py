"""repro.api: adapters, PruningSession resume-to-identical-result,
structured_prune, and the config-driven crossbar geometry on the
session path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CNNAdapter, FunctionAdapter, LMAdapter,
                       PruningSession, structured_prune)
from repro.configs import (CNNConfig, ConvSpec, PruneConfig, get_arch,
                           scaled_down)
from repro.core.masks import lm_prunable, sparsity_fraction


def _params(seed=0):
    r = np.random.RandomState(seed)
    return {"a": jnp.asarray(r.randn(3, 3, 4, 8), jnp.float32),
            "b": jnp.asarray(r.randn(256, 128), jnp.float32)}


def _scripted_adapter(params, cliff=0.45):
    """Deterministic adapter: accuracy collapses past ``cliff`` sparsity."""
    return FunctionAdapter(
        params=params,
        train_fn=lambda p, m: p,
        eval_fn=lambda p, m: 1.0 if sparsity_fraction(m) < cliff else 0.5,
        prunable=lambda p, l: l.ndim >= 2,
        conv_pred=lambda p: p == "a")


def test_session_runs_algorithm1_semantics():
    res = PruningSession(_scripted_adapter(_params()),
                         PruneConfig(prune_fraction=0.25, max_iters=20),
                         baseline_accuracy=1.0).run()
    assert 0.3 < res.sparsity < 0.45
    grans = [e.granularity for e in res.history]
    assert grans[0] == "filter"
    assert "channel" in grans and "index" in grans
    assert sum(not e.accepted for e in res.history) == 3


def test_session_streams_events_to_callbacks():
    seen = []
    res = PruningSession(_scripted_adapter(_params()),
                         PruneConfig(prune_fraction=0.25, max_iters=5),
                         baseline_accuracy=1.0,
                         callbacks=[seen.append]).run()
    assert len(seen) == len(res.history)
    assert [e.iteration for e in seen] == list(range(1, len(seen) + 1))


def test_interrupted_session_resumes_to_identical_result(tmp_path):
    params = _params()
    cfg = PruneConfig(prune_fraction=0.25, max_iters=20)
    full = PruningSession(_scripted_adapter(params), cfg,
                          baseline_accuracy=1.0).run()

    class Preempted(Exception):
        pass

    def preempt(event):
        if event.iteration == 2:
            raise Preempted()

    interrupted = PruningSession(_scripted_adapter(params), cfg,
                                 baseline_accuracy=1.0,
                                 ckpt_dir=str(tmp_path),
                                 callbacks=[preempt])
    with pytest.raises(Preempted):
        interrupted.run()

    resumed = PruningSession(_scripted_adapter(params), cfg,
                             baseline_accuracy=1.0,
                             ckpt_dir=str(tmp_path)).run()
    assert len(resumed.history) == len(full.history)
    for a, b in zip(full.history, resumed.history):
        assert (a.iteration, a.granularity, a.accepted) == \
            (b.iteration, b.granularity, b.accepted)
        assert a.sparsity_after == pytest.approx(b.sparsity_after, rel=1e-6)
    for x, y in zip(jax.tree.leaves(full.masks),
                    jax.tree.leaves(resumed.masks)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_allclose(np.asarray(full.params["b"]),
                               np.asarray(resumed.params["b"]))


def test_resume_preserves_float64_baseline(tmp_path):
    """The accept gate compares against the saved baseline; a float32
    restore template used to downcast it, which can flip
    ``acc >= baseline - tol`` after resume."""
    from repro.core.masks import make_masks

    params = _params()
    base = 0.75 + 2.0 ** -40            # representable only in float64
    assert float(np.float32(base)) != base
    sess = PruningSession(_scripted_adapter(params),
                          PruneConfig(max_iters=1),
                          baseline_accuracy=base, ckpt_dir=str(tmp_path))
    masks = make_masks(params, sess.adapter.prunable)
    sess._save({"stage_idx": 0, "step": 0, "itr": 1, "prune_rounds": 1},
               masks, base, [])

    resumed = PruningSession(_scripted_adapter(params),
                             PruneConfig(max_iters=1),
                             ckpt_dir=str(tmp_path))
    state, _, baseline, hist = resumed._restore(masks)
    assert state["itr"] == 1 and state["stage_idx"] == 0 and hist == []
    assert baseline == base             # bit-exact float64 round-trip


def test_session_geometry_64_changes_crossbar_accounting(tmp_path):
    """PruneConfig(xbar_rows=64, xbar_cols=64) flows through prune_step
    and the hardware report — same masks semantics, different tiling."""
    params = _params()
    res64 = PruningSession(
        _scripted_adapter(params),
        PruneConfig(prune_fraction=0.25, max_iters=4,
                    xbar_rows=64, xbar_cols=64),
        baseline_accuracy=1.0, granularities=["index"]).run()
    res128 = PruningSession(
        _scripted_adapter(params),
        PruneConfig(prune_fraction=0.25, max_iters=4),
        baseline_accuracy=1.0, granularities=["index"]).run()
    # 'index' groups are rows within one col-tile: 64-wide tiles make
    # strictly finer groups on the 128-col leaf, so the masks differ
    m64 = np.asarray(res64.masks["b"])
    m128 = np.asarray(res128.masks["b"])
    assert m64.shape == m128.shape and not np.array_equal(m64, m128)


def test_session_hardware_report_uses_config_geometry():
    params = {"b": jnp.asarray(
        np.random.RandomState(0).randn(128, 128), jnp.float32)}
    adapter = _scripted_adapter(params, cliff=2.0)     # accept everything
    s64 = PruningSession(adapter, PruneConfig(max_iters=1, xbar_rows=64,
                                              xbar_cols=64),
                         baseline_accuracy=1.0)
    s64.run()
    rep64 = s64.hardware_report()
    s128 = PruningSession(adapter, PruneConfig(max_iters=1),
                          baseline_accuracy=1.0)
    s128.run()
    rep128 = s128.hardware_report()
    assert rep64.xbars_unpruned == 4
    assert rep128.xbars_unpruned == 1


def test_export_ticket_and_init_params(tmp_path):
    params = _params()
    session = PruningSession(_scripted_adapter(params),
                             PruneConfig(prune_fraction=0.25, max_iters=3),
                             baseline_accuracy=1.0)
    res = session.run()
    np.testing.assert_array_equal(np.asarray(session.init_params["b"]),
                                  np.asarray(params["b"]))
    session.export_ticket(str(tmp_path / "ticket"))
    from repro.core import lottery
    w, m = lottery.import_ticket(str(tmp_path / "ticket"), params, res.masks)
    for a, b in zip(jax.tree.leaves(m), jax.tree.leaves(res.masks)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_structured_prune_schedule():
    params = _params()
    masks = structured_prune(
        params, [("filter", 0.2), ("index", 0.2)],
        prunable=lambda p, l: True, conv_pred=lambda p: p == "a")
    s = sparsity_fraction(masks)
    assert 0.3 <= s <= 0.5          # 1 - 0.8² within one group's slack


def test_cnn_adapter_end_to_end():
    cfg = CNNConfig(name="t-cnn", family="cnn",
                    convs=(ConvSpec(8, pool=True),), fc=(),
                    num_classes=10, image_size=8)
    adapter = CNNAdapter(cfg, steps=2, batch_size=8, eval_batches=1,
                         eval_batch_size=16)
    session = PruningSession(
        adapter, PruneConfig(prune_fraction=0.3, max_iters=1,
                             accuracy_tolerance=1.0))
    res = session.run()
    assert res.sparsity > 0.2
    assert len(res.history) == 1
    acc = adapter.evaluate(res.params, res.masks)
    assert 0.0 <= acc <= 1.0


def test_lm_adapter_train_eval_and_serve_fns():
    cfg = scaled_down(get_arch("llama3.2-3b"), n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, head_dim=16,
                      vocab_size=64, dtype="float32")
    adapter = LMAdapter(cfg, steps=2, batch_size=2, seq_len=8,
                        eval_batches=1)
    params = adapter.init_params(jax.random.PRNGKey(0))
    score0 = adapter.evaluate(params)
    assert np.isfinite(score0) and score0 < 0          # -CE
    trained = adapter.train(params, None, steps=2)
    assert np.isfinite(adapter.last_metrics["loss"])
    masks = structured_prune(trained, [("filter", 0.25)],
                             prunable=lm_prunable)
    assert sparsity_fraction(masks) > 0.1
    prefill_fn, decode_fn = adapter.serve_fns()
    assert callable(prefill_fn) and callable(decode_fn)


def test_function_adapter_requires_no_rng_state():
    params = _params()
    ad = _scripted_adapter(params)
    p1 = ad.init_params(jax.random.PRNGKey(0))
    p2 = ad.init_params(jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(p1["b"]), np.asarray(p2["b"]))
