"""Optimizers: convergence, masking invariants, clipping, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masks import make_masks
from repro.optim import (adamw, constant, cosine_decay,
                         exponential_epoch_decay, masked, sgd,
                         warmup_cosine, with_gradient_clipping)


def quad_loss(params):
    return sum(jnp.sum((p - 3.0) ** 2) for p in jax.tree.leaves(params))


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(constant(0.1), momentum=0.9),
    lambda: adamw(constant(0.1), weight_decay=0.0),
    lambda: with_gradient_clipping(sgd(constant(0.1)), 1.0),
])
def test_converges_on_quadratic(make_opt):
    params = {"a": jnp.zeros((4, 4)), "b": jnp.zeros((8,))}
    opt = make_opt()
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(quad_loss)(params)
        params, state = opt.update(g, state, params)
    assert quad_loss(params) < 1e-2


def test_masked_optimizer_keeps_zeros_exact():
    params = {"w": jnp.ones((16, 16))}
    masks = make_masks(params, lambda p, l: True)
    m = np.ones((16, 16), np.float32)
    m[::2] = 0.0
    masks = {"w": jnp.asarray(m)}
    from repro.core.masks import apply_masks
    params = apply_masks(params, masks)
    opt = masked(sgd(constant(0.2), momentum=0.0), masks)
    state = opt.init(params)
    for _ in range(50):
        g = jax.grad(quad_loss)(params)
        params, state = opt.update(g, state, params)
    arr = np.asarray(params["w"])
    assert (arr[::2] == 0.0).all()            # pruned stay exactly zero
    assert (np.abs(arr[1::2] - 3.0) < 0.1).all()  # survivors train


def test_gradient_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    opt = with_gradient_clipping(sgd(constant(1.0), momentum=0.0), 0.5)
    state = opt.init(params)
    g = {"w": jnp.full((4,), 100.0)}
    new, _ = opt.update(g, state, params)
    assert float(jnp.linalg.norm(new["w"])) <= 0.5 + 1e-5


def test_paper_lr_schedule():
    """Paper: LR 0.1 decreased by 5% every epoch."""
    fn = exponential_epoch_decay(0.1, 0.95, steps_per_epoch=100)
    assert float(fn(0)) == pytest.approx(0.1)
    assert float(fn(100)) == pytest.approx(0.095)
    assert float(fn(1000)) == pytest.approx(0.1 * 0.95 ** 10)


def test_warmup_cosine_monotone_warmup():
    fn = warmup_cosine(1.0, 10, 100)
    vals = [float(fn(i)) for i in range(10)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    assert float(fn(100)) == pytest.approx(0.1, rel=0.05)


def test_adamw_weight_decay_pulls_to_zero():
    params = {"w": jnp.full((4,), 5.0)}
    opt = adamw(constant(0.1), weight_decay=0.5)
    state = opt.init(params)
    zero_grad = {"w": jnp.zeros((4,))}
    for _ in range(100):
        params, state = opt.update(zero_grad, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1.0
