"""Whisper-style encoder-decoder: shapes, cache continuity, training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, scaled_down
from repro.models import encdec

B, S = 2, 16


@pytest.fixture(scope="module")
def setup():
    cfg = scaled_down(get_arch("whisper-tiny"), dtype="float32")
    params = encdec.init_params(jax.random.PRNGKey(0), cfg)
    rng = jax.random.PRNGKey(1)
    frames = jax.random.normal(rng, (B, cfg.encoder_seq_len, cfg.d_model))
    tokens = jax.random.randint(rng, (B, S), 0, 100)
    return cfg, params, frames, tokens


def test_encoder_output_shape(setup):
    cfg, params, frames, _ = setup
    enc = encdec.encode(params, cfg, frames)
    assert enc.shape == (B, cfg.encoder_seq_len, cfg.d_model)
    assert np.isfinite(np.asarray(enc)).all()


def test_forward_and_loss(setup):
    cfg, params, frames, tokens = setup
    batch = {"frames": frames, "tokens": tokens, "labels": tokens}
    logits, _ = encdec.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    loss, _ = encdec.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))


def test_prefill_matches_forward(setup):
    cfg, params, frames, tokens = setup
    batch = {"frames": frames, "tokens": tokens}
    logits_full, _ = encdec.forward(params, cfg,
                                    {**batch, "labels": tokens})
    lg, caches = encdec.prefill(params, cfg, batch, capacity=S + 8)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_decode_continuity(setup):
    cfg, params, frames, tokens = setup
    ext = jnp.concatenate([tokens, tokens[:, :1]], axis=1)
    logits_ext, _ = encdec.forward(params, cfg,
                                   {"frames": frames, "tokens": ext,
                                    "labels": ext})
    _, caches = encdec.prefill(params, cfg,
                               {"frames": frames, "tokens": tokens},
                               capacity=S + 8)
    lg, caches = encdec.decode_step(params, cfg, caches, tokens[:, :1])
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(logits_ext[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_cross_kv_computed_once(setup):
    """Decode must not re-encode: cross KV identical across steps."""
    cfg, params, frames, tokens = setup
    _, caches = encdec.prefill(params, cfg,
                               {"frames": frames, "tokens": tokens},
                               capacity=S + 8)
    k_before = np.asarray(caches[0]["cross"].k)
    _, caches = encdec.decode_step(params, cfg, caches, tokens[:, :1])
    np.testing.assert_array_equal(k_before,
                                  np.asarray(caches[0]["cross"].k))
