"""Hypothesis property tests on the system's invariants.

Skipped (not errored) when the optional ``hypothesis`` dev extra is
absent, so a bare environment still collects and runs the tier-1 suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import crossbar as xb
from repro.core import scoring
from repro.core.algorithm import prune_step
from repro.core.masks import (apply_masks, make_masks, sparsity_fraction)
from repro.kernels.bsmm import bsmm_pallas, compact_tile_indices
from repro.kernels.ref import bsmm_ref

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def mask_matrix(draw, max_dim=96):
    r = draw(st.integers(4, max_dim))
    c = draw(st.integers(4, max_dim))
    density = draw(st.floats(0.0, 1.0))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.RandomState(seed)
    return (rng.rand(r, c) < density)


@given(mask_matrix())
@settings(**SETTINGS)
def test_xbar_stats_invariants(m):
    st_ = xb.xbar_stats(m, xr=32, xc=32)
    assert st_.total_cells == m.size
    assert st_.nonzero_cells == int(m.sum())
    # savings bounded by pruned cells
    assert 0 <= st_.saved_cells <= m.size - st_.nonzero_cells
    # packed never exceeds strict; strict never exceeds grid
    assert st_.xbars_needed_packed <= st_.xbars_needed_strict
    assert st_.xbars_needed_strict + st_.xbars_fully_free == st_.n_xbars
    # live area covers all nonzeros
    assert st_.live_area >= st_.nonzero_cells


@given(mask_matrix(max_dim=64))
@settings(**SETTINGS)
def test_compact_indices_cover_exactly_live_tiles(m):
    tm = xb.xbar_stats  # noqa: F841  (import guard)
    bits = m[: (m.shape[0] // 8) * 8, : (m.shape[1] // 8) * 8]
    if bits.size == 0:
        return
    tiles = bits.reshape(bits.shape[0] // 8, 8, bits.shape[1] // 8, 8)
    live = tiles.any(axis=(1, 3)).astype(np.int32)
    idx, counts, kmax = compact_tile_indices(live)
    assert counts.sum() == live.sum()
    assert kmax >= max(1, counts.max())
    for j in range(live.shape[1]):
        assert sorted(idx[j, :counts[j]].tolist()) == \
            np.nonzero(live[:, j])[0].tolist()


@given(st.integers(0, 2 ** 16), st.floats(0.05, 0.6),
       st.sampled_from(["filter", "channel", "index", "ltp", "block",
                        "cap"]))
@settings(**SETTINGS)
def test_prune_step_monotone_and_calibrated(seed, frac, gran):
    rng = np.random.RandomState(seed)
    params = {"conv": jnp.asarray(rng.randn(3, 3, 8, 16), jnp.float32),
              "fc": jnp.asarray(rng.randn(130, 70), jnp.float32)}
    masks = make_masks(params, lambda p, l: True)
    new = prune_step(params, masks, gran, frac, lambda p: p == "conv")
    # monotone: no resurrection
    for a, b in zip(jax.tree.leaves(masks), jax.tree.leaves(new)):
        assert (np.asarray(b) <= np.asarray(a)).all()
    s = sparsity_fraction(new)
    # hits the requested fraction within one (coarsest) group's size
    assert s >= frac - 0.02
    assert s <= min(1.0, frac + 0.35)


@given(st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_bsmm_random_tile_masks(seed):
    rng = np.random.RandomState(seed)
    b = 16
    M, K, N = 32, 64, 48
    x = jnp.asarray(rng.randn(M, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    tm = (rng.rand(K // b, N // b) > rng.rand()).astype(np.int32)
    out = bsmm_pallas(x, w, tm, bm=b, bk=b, bn=b, interpret=True)
    ref = bsmm_ref(x, w, tm, b, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


@given(st.integers(0, 2 ** 16), st.floats(0.1, 0.9))
@settings(**SETTINGS)
def test_apply_masks_idempotent_and_sparsity_exact(seed, density):
    rng = np.random.RandomState(seed)
    params = {"w": jnp.asarray(rng.randn(32, 32), jnp.float32)}
    m = (rng.rand(32, 32) < density).astype(np.float32)
    masks = {"w": jnp.asarray(m)}
    once = apply_masks(params, masks)
    twice = apply_masks(once, masks)
    np.testing.assert_array_equal(np.asarray(once["w"]),
                                  np.asarray(twice["w"]))
    assert (np.asarray(once["w"])[m == 0] == 0).all()


@given(st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_conv_unroll_is_bijection(seed):
    rng = np.random.RandomState(seed)
    k = rng.choice([1, 3, 5])
    ic, oc = rng.randint(1, 12), rng.randint(1, 12)
    w = rng.randn(k, k, ic, oc)
    np.testing.assert_array_equal(
        xb.matrix_to_conv(xb.conv_to_matrix(w), w.shape), w)


@given(mask_matrix(max_dim=80))
@settings(**SETTINGS)
def test_group_zeroing_kills_exactly_requested(m):
    w = np.random.RandomState(0).randn(*m.shape).astype(np.float32)
    mask = m.astype(np.float32)
    gs = scoring.group_scores("p", w, mask, "filter", conv=False)
    alive_cols = np.nonzero(gs.alive[0])[0]
    if len(alive_cols) == 0:
        return
    kill = np.zeros_like(gs.alive)
    kill[0, alive_cols[0]] = True
    new = scoring.zero_groups(mask, gs, kill)
    assert new[:, alive_cols[0]].sum() == 0
    others = np.delete(np.arange(m.shape[1]), alive_cols[0])
    np.testing.assert_array_equal(new[:, others], mask[:, others])


@given(st.integers(0, 2 ** 16), st.floats(0.1, 0.95))
@settings(max_examples=15, deadline=None)
def test_pack_ffn_equivalence_random_masks(seed, dead_frac):
    """Packed FFN == masked FFN for any column-structured mask."""
    from repro.core.packing import pack_ffn
    rng = np.random.RandomState(seed)
    d, ff = 16, 256
    up = rng.randn(d, ff).astype(np.float32)
    gate = rng.randn(d, ff).astype(np.float32)
    down = rng.randn(ff, d).astype(np.float32)
    dead = rng.rand(ff) < dead_frac
    m = np.ones((d, ff), np.float32)
    m[:, dead] = 0.0
    md = np.ones((ff, d), np.float32)
    md[dead, :] = 0.0
    up_p, gate_p, down_p, ffp = pack_ffn(up, gate, down, m, m, md)
    assert ffp % 128 == 0 or ffp == ff
    x = rng.randn(3, d).astype(np.float32)
    ref = (jax.nn.silu(x @ (gate * m)) * (x @ (up * m))) @ (down * md)
    got = (jax.nn.silu(x @ gate_p) * (x @ up_p)) @ down_p
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2 ** 16), st.sampled_from([8, 16]))
@settings(max_examples=15, deadline=None)
def test_quantize_roundtrip_bounded(seed, bits):
    """|dequant(quant(w)) - w| <= scale/2 per output channel."""
    from repro.core.quantize import dequantize, quantize
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(24, 12) * rng.uniform(0.01, 10), jnp.float32)
    qt = quantize(w, bits)
    back = dequantize(qt, jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(w))
    # half-ulp rounding bound with float32 slack on the q·scale product
    bound = np.asarray(qt.scale)[0] * 0.502 + 1e-7
    assert (err <= bound[None, :]).all()


# ---------------------------------------------------------------------------
# kernel auditor (K3xx): every valid plan passes, every corruption fails
# ---------------------------------------------------------------------------
def _fwd_audit_inputs(bitmap, tile=8, mt=2):
    """Random bitmap → (spec, truth, cost) for the bsmm fwd kernel."""
    from repro.core.perf_model import bsmm_fwd_cost
    from repro.kernels.bsmm import bsmm_fwd_spec, make_tile_plan
    kt, nt = bitmap.shape
    m = mt * tile
    mask = np.repeat(np.repeat(bitmap, tile, 0), tile, 1)
    plan = make_tile_plan(mask, tile=tile, strict=True)
    spec = bsmm_fwd_spec(plan.idx, plan.counts, plan.kmax, M=m,
                         K=kt * tile, N=nt * tile, bm=tile, bk=tile,
                         bn=tile)
    truth = {
        name: {(i, j): [((i, int(k)) if name == "x" else (int(k), j))
                        for k in np.nonzero(bitmap[:, j])[0]]
               for i in range(mt) for j in range(nt)}
        for name in ("x", "w")}
    return plan, spec, truth, bsmm_fwd_cost(plan, m, bm=tile)


@st.composite
def tile_bitmap_(draw):
    kt = draw(st.integers(2, 4))
    nt = draw(st.integers(2, 4))
    seed = draw(st.integers(0, 2 ** 16))
    density = draw(st.floats(0.1, 1.0))
    rng = np.random.RandomState(seed)
    return (rng.rand(kt, nt) < density).astype(np.int32)


@given(tile_bitmap_())
@settings(**SETTINGS)
def test_valid_tile_plans_always_pass_kernel_audit(bitmap):
    """Any evenly-tiling plan yields a spec that is K-clean: coverage
    exact, all gathers in bounds, guard == the bitmap's liveness, and
    the perf model agreeing with the spec enumeration."""
    from repro.analysis import audit_kernel_spec
    _, spec, truth, cost = _fwd_audit_inputs(bitmap)
    findings = audit_kernel_spec(spec, expected_gathers=truth, cost=cost)
    assert findings == [], findings


@given(tile_bitmap_())
@settings(**SETTINGS)
def test_corrupted_gather_index_always_fails_audit(bitmap):
    """Pointing any live idx slot past the K tile grid is always K302."""
    from hypothesis import assume
    from repro.analysis import audit_kernel_spec
    from repro.kernels.bsmm import bsmm_fwd_spec
    plan, spec, truth, cost = _fwd_audit_inputs(bitmap)
    assume(plan.counts.max() > 0)
    j = int(np.argmax(plan.counts))
    bad_idx = np.array(plan.idx)
    bad_idx[j, 0] = bitmap.shape[0]          # first live slot, off the edge
    bad = bsmm_fwd_spec(bad_idx, plan.counts, plan.kmax, M=16,
                        K=bitmap.shape[0] * 8, N=bitmap.shape[1] * 8,
                        bm=8, bk=8, bn=8)
    assert "K302" in {f.code for f in audit_kernel_spec(bad)}


@given(tile_bitmap_())
@settings(**SETTINGS)
def test_corrupted_output_map_always_fails_coverage(bitmap):
    """Collapsing the output index map onto row 0 is always K301."""
    import dataclasses
    from repro.analysis import audit_kernel_spec
    _, spec, _, _ = _fwd_audit_inputs(bitmap)
    o = spec.outputs[0]
    bad = dataclasses.replace(
        spec, outputs=(dataclasses.replace(
            o, index_map=lambda i, j, k, cnt, idx: (0, j)),))
    assert "K301" in {f.code for f in audit_kernel_spec(bad)}


@given(tile_bitmap_())
@settings(**SETTINGS)
def test_loosened_guard_always_fails_liveness(bitmap):
    """Unmasking one dead slot always breaks K303 against the truth."""
    import dataclasses
    from hypothesis import assume
    from repro.analysis import audit_kernel_spec
    plan, spec, truth, _ = _fwd_audit_inputs(bitmap)
    assume(int(plan.counts.min()) < int(plan.kmax))   # a dead slot exists
    kmax = int(plan.kmax)
    bad = dataclasses.replace(
        spec, guard=lambda i, j, k, cnt, idx: bool(k <= cnt[j])
        and k < kmax)
    findings = audit_kernel_spec(bad, expected_gathers=truth)
    assert "K303" in {f.code for f in findings}
