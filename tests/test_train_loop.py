"""Trainer: convergence, microbatching, checkpoint-resume, stragglers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataPipeline, SyntheticLM
from repro.optim import adamw, constant, masked, sgd
from repro.train import Trainer, make_train_step


def _tiny_lm():
    """2-layer MLP LM on the markov stream."""
    import jax.random as jr
    V, D, S = 32, 16, 16
    ks = jr.split(jr.PRNGKey(0), 3)
    params = {"emb": jr.normal(ks[0], (V, D)) * 0.1,
              "w1": jr.normal(ks[1], (2 * D, 4 * D)) * 0.1,
              "w2": jr.normal(ks[2], (4 * D, V)) * 0.1}

    def loss_fn(params, batch):
        x = params["emb"][batch["tokens"]]              # (B,S,D)
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        h = jnp.concatenate([x, prev], -1)
        h = jax.nn.relu(h @ params["w1"])
        logits = h @ params["w2"]
        ll = jax.nn.log_softmax(logits)
        loss = -jnp.take_along_axis(
            ll, batch["labels"][..., None], -1).mean()
        return loss, {}

    gen = SyntheticLM(vocab_size=V, seq_len=S, seed=0, noise=0.0)
    return params, loss_fn, gen


def test_train_step_reduces_loss():
    params, loss_fn, gen = _tiny_lm()
    opt = adamw(constant(1e-2))
    step = make_train_step(loss_fn, opt, donate=False)
    opt_state = opt.init(params)
    losses = []
    for i in range(150):
        b = {k: jnp.asarray(v) for k, v in gen.batch(i, 16).items()}
        params, opt_state, m = step(params, opt_state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.85


def test_microbatching_matches_full_batch():
    params, loss_fn, gen = _tiny_lm()
    opt = sgd(constant(0.1), momentum=0.0)
    full = make_train_step(loss_fn, opt, donate=False)
    micro = make_train_step(loss_fn, opt, microbatch=4, donate=False)
    b = {k: jnp.asarray(v) for k, v in gen.batch(0, 16).items()}
    s0 = opt.init(params)
    p1, _, m1 = full(params, s0, b)
    s0 = opt.init(params)
    p2, _, m2 = micro(params, s0, b)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-5, atol=1e-6)


def test_trainer_checkpoint_resume(tmp_path):
    params, loss_fn, gen = _tiny_lm()

    def make_trainer():
        pipe = DataPipeline(
            lambda s: {k: jnp.asarray(v) for k, v in gen.batch(s, 8).items()},
            prefetch=0)
        return Trainer(loss_fn=loss_fn, optimizer=adamw(constant(1e-3)),
                       params=params, data_iter=pipe,
                       ckpt_dir=str(tmp_path), ckpt_every=5,
                       async_ckpt=False)

    t1 = make_trainer()
    t1.run(10, log_every=0)
    w_after_10 = np.asarray(t1.state.params["w1"]).copy()
    # new trainer resumes from step 10, not 0
    t2 = make_trainer()
    assert t2.state.step == 10
    np.testing.assert_allclose(np.asarray(t2.state.params["w1"]),
                               w_after_10, rtol=1e-6)
    t2.run(5, log_every=0)
    assert t2.state.step == 15


def test_straggler_callback_fires():
    params, loss_fn, gen = _tiny_lm()
    events = []
    pipe = DataPipeline(
        lambda s: {k: jnp.asarray(v) for k, v in gen.batch(s, 8).items()},
        prefetch=0)
    t = Trainer(loss_fn=loss_fn, optimizer=adamw(constant(1e-3)),
                params=params, data_iter=pipe, ckpt_dir=None,
                step_deadline_s=0.0,          # everything is a straggler
                on_straggler=lambda step, dt: events.append((step, dt)))
    t.run(3, log_every=0)
    assert len(events) == 3
