"""The block-sparse training path: custom-VJP gradients vs the dense
masked oracle, and the tile-pass accounting behind the paper's
"pruning makes retraining faster" claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masks import mask_grads
from repro.kernels import ref
from repro.kernels.bsmm import make_tile_plan, plan_matmul
from repro.kernels.ops import sparse_dense
from repro.models.attention import gqa_forward, gqa_init
from repro.models.layers import mlp, mlp_init
from repro.train.plans import cnn_train_plan, lm_train_plan

TOL = dict(rtol=1e-5, atol=1e-4)


def _random_mask(rng, K, N, density=0.4, tile=128):
    """Elementwise mask with ~``density`` live elements AND at least one
    fully-dead 128x128 tile column when the shape allows."""
    m = (rng.rand(K, N) < density).astype(np.float32)
    if N >= 2 * tile:
        m[:, tile:2 * tile] = 0.0          # all-dead output tile column
    return m


def _grads(fn, *args):
    return jax.grad(lambda *a: jnp.sum(jnp.square(fn(*a))),
                    argnums=tuple(range(len(args))))(*args)


# -- sparse_dense: direct oracle equivalence --------------------------------
@pytest.mark.parametrize("M,K,N", [
    (8, 256, 128),       # MLP up-proj shape
    (16, 128, 128),      # attention projection shape
    (64, 256, 256),      # FC shape (all-dead tile column case)
    (5, 256, 128),       # ragged-M retrain microbatch
    (3, 128, 384),       # ragged M, wide N
])
def test_sparse_dense_grads_match_dense_oracle(M, K, N):
    rng = np.random.RandomState(M * 7 + K + N)
    mask = _random_mask(rng, K, N)
    x = jnp.asarray(rng.randn(M, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)

    def s_fn(x, w):
        return sparse_dense(x, w, mask)

    def d_fn(x, w):
        return ref.masked_matmul_ref(x, w, jnp.asarray(mask))

    np.testing.assert_allclose(np.asarray(s_fn(x, w)),
                               np.asarray(d_fn(x, w)), **TOL)
    (dxs, dws), (dxd, dwd) = _grads(s_fn, x, w), _grads(d_fn, x, w)
    # grads: same math, different accumulation order → slightly wider tol
    np.testing.assert_allclose(np.asarray(dxs), np.asarray(dxd),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(dws), np.asarray(dwd),
                               rtol=1e-4, atol=1e-3)


def test_sparse_dense_grad_all_dead_mask_is_zero():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(8, 128), jnp.float32)
    w = jnp.asarray(rng.randn(128, 128), jnp.float32)
    mask = np.zeros((128, 128), np.float32)
    out = sparse_dense(x, w, mask)
    assert float(jnp.abs(out).max()) == 0.0
    dx, dw = _grads(lambda x, w: sparse_dense(x, w, mask), x, w)
    assert float(jnp.abs(dx).max()) == 0.0
    assert float(jnp.abs(dw).max()) == 0.0


def test_sparse_dense_ragged_m_stays_on_kernel(monkeypatch):
    """M that doesn't tile is sublane-padded through the kernel now —
    the dense oracle fallback is reserved for ragged K/N."""
    def boom(*a, **k):
        raise AssertionError("dense fallback used for ragged M")
    monkeypatch.setattr(ref, "masked_matmul_ref", boom)
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(3, 128), jnp.float32)
    w = jnp.asarray(rng.randn(128, 128), jnp.float32)
    out = sparse_dense(x, w, np.ones((128, 128), np.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w), **TOL)
    # ragged K still falls back (and the monkeypatch proves it)
    with pytest.raises(AssertionError, match="dense fallback"):
        sparse_dense(jnp.asarray(rng.randn(4, 100), jnp.float32),
                     jnp.asarray(rng.randn(100, 128), jnp.float32),
                     np.ones((100, 128), np.float32))


# -- fused bias+activation epilogue -----------------------------------------
@pytest.mark.parametrize("act", [None, "relu", "gelu", "silu"])
@pytest.mark.parametrize("with_bias", [True, False])
def test_epilogue_fused_matches_unfused_oracle(act, with_bias):
    """``plan_matmul(..., bias=b, act=a)`` fuses the epilogue into the
    kernel flush; forward and all grads (incl. db) must match the
    unfused two-pass oracle on live tiles."""
    if act is None and not with_bias:
        pytest.skip("no epilogue — identical to the plain path")
    from repro.kernels.bsmm import _EPILOGUE_ACTS
    rng = np.random.RandomState(11)
    M, K, N = 24, 256, 384
    mask = _random_mask(rng, K, N)
    plan = make_tile_plan(mask)
    x = jnp.asarray(rng.randn(M, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N) * mask, jnp.float32)
    b = jnp.asarray(rng.randn(N), jnp.float32) if with_bias else None
    fn = _EPILOGUE_ACTS.get(act, lambda z: z)

    def fused(x, w, b):
        return plan_matmul(x, w, plan, bias=b, act=act)

    def oracle(x, w, b):
        z = plan_matmul(x, w, plan)
        return fn(z if b is None else z + b)

    np.testing.assert_allclose(np.asarray(fused(x, w, b)),
                               np.asarray(oracle(x, w, b)), **TOL)
    args = (x, w, b) if with_bias else (x, w)
    loss_f = lambda *a: jnp.sum(jnp.sin(fused(*a, *(() if with_bias else (None,)))))
    loss_o = lambda *a: jnp.sum(jnp.sin(oracle(*a, *(() if with_bias else (None,)))))
    gf = jax.grad(loss_f, argnums=tuple(range(len(args))))(*args)
    go = jax.grad(loss_o, argnums=tuple(range(len(args))))(*args)
    names = ("dx", "dw", "db")[:len(args)]
    for name, a, o in zip(names, gf, go):
        np.testing.assert_allclose(np.asarray(a), np.asarray(o),
                                   rtol=1e-4, atol=2e-3, err_msg=name)


def test_epilogue_rejects_unknown_activation():
    rng = np.random.RandomState(12)
    mask = np.ones((128, 128), np.float32)
    plan = make_tile_plan(mask)
    x = jnp.asarray(rng.randn(8, 128), jnp.float32)
    w = jnp.asarray(rng.randn(128, 128), jnp.float32)
    with pytest.raises(ValueError, match="unsupported epilogue act"):
        plan_matmul(x, w, plan, act="tanh")
    with pytest.raises(ValueError, match="unsupported epilogue act"):
        plan_matmul(x, w, None, act="tanh")


# -- model layers: plan path vs dense on pre-masked params ------------------
# Inside a live tile the kernel's dw covers the whole tile (the
# elementwise mask is the masked optimizer's job), so the comparison
# against the dense path is through ``mask_grads`` — the quantity the
# optimizer actually consumes.
def test_mlp_plan_grads_match_dense():
    rng = np.random.RandomState(2)
    d_model, d_ff, B, S = 128, 256, 2, 8
    params = mlp_init(jax.random.PRNGKey(0), d_model, d_ff, gated=True)
    masks = {k: jnp.asarray(_random_mask(rng, *params[k].shape))
             for k in ("up", "gate", "down")}
    params = {k: params[k] * masks[k] for k in params}
    plan = {k: make_tile_plan(np.asarray(masks[k])) for k in masks}
    assert all(p is not None for p in plan.values())
    x = jnp.asarray(rng.randn(B, S, d_model), jnp.float32)

    def loss_plan(p):
        return jnp.sum(jnp.square(mlp(p, x, plan=plan)))

    def loss_dense(p):
        return jnp.sum(jnp.square(mlp(p, x)))

    np.testing.assert_allclose(float(loss_plan(params)),
                               float(loss_dense(params)), rtol=1e-5)
    gp = mask_grads(jax.grad(loss_plan)(params), masks)
    gd = mask_grads(jax.grad(loss_dense)(params), masks)
    for k in params:
        np.testing.assert_allclose(np.asarray(gp[k]), np.asarray(gd[k]),
                                   rtol=1e-4, atol=1e-3)


def test_gqa_forward_plan_grads_match_dense():
    rng = np.random.RandomState(3)
    d_model, n_heads, head_dim, B, S = 128, 2, 64, 2, 8
    params = gqa_init(jax.random.PRNGKey(0), d_model, n_heads, n_heads,
                      head_dim)
    keys = ("wq", "wk", "wv", "wo")
    masks = {k: jnp.asarray(_random_mask(rng, *params[k].shape))
             for k in keys}
    params = {k: params[k] * masks[k] for k in params}
    plan = {k: make_tile_plan(np.asarray(masks[k])) for k in keys}
    assert all(p is not None for p in plan.values())
    x = jnp.asarray(rng.randn(B, S, d_model), jnp.float32)
    kw = dict(n_heads=n_heads, n_kv_heads=n_heads, head_dim=head_dim,
              rope_theta=10_000.0)

    def loss_plan(p):
        return jnp.sum(jnp.square(gqa_forward(p, x, plan=plan, **kw)))

    def loss_dense(p):
        return jnp.sum(jnp.square(gqa_forward(p, x, **kw)))

    np.testing.assert_allclose(float(loss_plan(params)),
                               float(loss_dense(params)), rtol=1e-5)
    gp = mask_grads(jax.grad(loss_plan)(params), masks)
    gd = mask_grads(jax.grad(loss_dense)(params), masks)
    for k in keys:
        np.testing.assert_allclose(np.asarray(gp[k]), np.asarray(gd[k]),
                                   rtol=1e-4, atol=1e-3)


def test_cnn_non_tiling_shapes_stay_dense():
    """Shapes that don't tile 128 get no plan and the forward still runs
    (everything dense) — the small-config safety net."""
    from repro.configs.base import CNNConfig, ConvSpec
    from repro.models import cnn as cnn_lib
    rng = np.random.RandomState(4)
    cfg = CNNConfig(name="tiny-fc", family="vgg", convs=(ConvSpec(16),),
                    fc=(128,), num_classes=10, image_size=8)
    params, state = cnn_lib.init_params(jax.random.PRNGKey(0), cfg)
    masks = {"convs": [None], "bns": [None],
             "shortcuts": {},
             "fc": [{"w": jnp.asarray(_random_mask(rng, 16, 128)),
                     "b": None}],
             "head": {"w": jnp.asarray(_random_mask(rng, 128, 10)),
                      "b": None}}
    plans, stats = cnn_train_plan(masks)
    # neither (16,128) nor (128,10) tiles at 128 — everything stays dense
    assert plans is None and stats.routed == 0 and stats.dense_fallback == 2
    images = jnp.asarray(rng.randn(4, 8, 8, 3), jnp.float32)
    logits, _ = cnn_lib.forward(params, state, cfg, images, plans=plans)
    assert logits.shape == (4, 10)


def test_cnn_fc_plan_grads_match_dense():
    """A CNN whose GAP feature width tiles 128: the FC layer is routed
    block-sparse through ``cnn.forward`` and the loss/grads of the plan
    path agree with the dense path on pre-masked weights."""
    from repro.configs.base import CNNConfig, ConvSpec
    from repro.models import cnn as cnn_lib
    rng = np.random.RandomState(5)
    cfg = CNNConfig(name="fc-128", family="vgg", convs=(ConvSpec(128),),
                    fc=(256,), num_classes=10, image_size=8)
    params, state = cnn_lib.init_params(jax.random.PRNGKey(0), cfg)
    m1 = jnp.asarray(_random_mask(rng, 128, 256))
    masks = {"fc": [{"w": m1, "b": None}], "head": None}
    plans, stats = cnn_train_plan(masks)
    assert plans is not None and stats.routed == 1
    assert plans["fc"][0] is not None and plans["head"] is None
    params["fc"][0]["w"] = params["fc"][0]["w"] * m1
    images = jnp.asarray(rng.randn(4, 8, 8, 3), jnp.float32)
    labels = jnp.asarray(rng.randint(0, 10, size=(4,)), jnp.int32)
    batch = {"images": images, "labels": labels}

    def loss(p, plans):
        l, _ = cnn_lib.loss_fn(p, state, cfg, batch, train=True, plans=plans)
        return l

    lp = float(loss(params, plans))
    ld = float(loss(params, None))
    np.testing.assert_allclose(lp, ld, rtol=1e-5)
    gp = jax.grad(loss)(params, plans)
    gd = jax.grad(loss)(params, None)
    grad_masks = jax.tree.map(lambda _: None, params)
    grad_masks["fc"][0]["w"] = m1
    gp, gd = mask_grads(gp, grad_masks), mask_grads(gd, grad_masks)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4), gp, gd)


# -- the acceptance accounting: fewer passes at low density -----------------
def test_retrain_step_low_density_executes_fewer_passes():
    """A <=10%-tile-density plan must run strictly fewer K-grid passes
    (fwd), N-grid passes (dx) and weight-grad tiles (dw) than dense —
    the static counts the TPU grid actually executes — and a jitted
    train step closed over the plan must still descend the loss."""
    rng = np.random.RandomState(6)
    K = N = 512
    tile = 128
    Kt, Nt = K // tile, N // tile
    mask = np.zeros((K, N), np.float32)
    mask[:tile, :tile] = 1.0               # 1 of 16 tiles live (6.25%)
    plan = make_tile_plan(mask)
    assert plan.live_tiles / plan.total_tiles <= 0.10
    # strict pass reductions vs the dense grid
    assert plan.kmax < Kt                  # forward: K-grid passes
    assert plan.nmax < Nt                  # dx: transposed N-grid passes
    assert plan.live_tiles < Kt * Nt       # dw: materialised grad tiles
    assert int(plan.counts.sum()) == plan.live_tiles

    w = jnp.asarray(rng.randn(K, N) * mask, jnp.float32)
    x = jnp.asarray(rng.randn(16, K), jnp.float32)
    y = jnp.asarray(rng.randn(16, N), jnp.float32)

    @jax.jit
    def step(w):
        def loss(w):
            return jnp.mean(jnp.square(plan_matmul(x, w, plan) - y))
        l, g = jax.value_and_grad(loss)(w)
        return l, w - 0.01 * g

    l0, w1 = step(w)
    l1, _ = step(w1)
    assert np.isfinite(float(l0)) and float(l1) < float(l0)
    # weight grads outside live tiles are identically zero → the update
    # never resurrects a dead tile
    dead = np.asarray(w1)[tile:, tile:]
    assert float(np.abs(dead).max()) == 0.0


def test_lm_adapter_retrains_through_bsmm():
    """End to end: LMAdapter with use_bsmm=True closes a mask-derived
    plan into the jitted train step, trains without NaNs, and records
    the routed-matmul stats the session logs per retrain round."""
    from repro.api import LMAdapter
    from repro.configs import get_arch, scaled_down
    from repro.core.masks import lm_prunable, make_masks
    cfg = scaled_down(get_arch("llama3.2-3b"), d_model=128, n_layers=2,
                      n_heads=2, n_kv_heads=2, d_ff=256, head_dim=64,
                      vocab_size=128)
    ad = LMAdapter(cfg, steps=2, batch_size=2, seq_len=16, use_bsmm=True,
                   bsmm_interpret=True)
    params = ad.init_params(jax.random.PRNGKey(0))
    masks = make_masks(params, lm_prunable)
    rng = np.random.RandomState(7)
    masks = jax.tree.map(
        lambda m: (m * jnp.asarray(_random_mask(rng, *m.shape[-2:]))
                   if m is not None and m.ndim >= 2 else m),
        masks, is_leaf=lambda x: x is None)
    p2 = ad.train(params, masks, steps=2)
    assert ad.last_plan_stats.routed > 0
    assert 0.0 < ad.last_plan_stats.skipped_tile_fraction < 1.0
    assert np.isfinite(ad.evaluate(p2, masks))


def test_lm_train_plan_matches_decode_plan_structure():
    from repro.configs import get_arch, scaled_down
    from repro.core.masks import lm_prunable, make_masks
    from repro.models import transformer as tfm
    from repro.models.plans import build_decode_plan
    cfg = scaled_down(get_arch("llama3.2-3b"), d_model=128, n_layers=2,
                      n_heads=2, n_kv_heads=2, d_ff=256, head_dim=64,
                      vocab_size=128)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    masks = make_masks(params, lm_prunable)
    train_plan, t_stats = lm_train_plan(masks, interpret=True)
    decode_plan, d_stats = build_decode_plan(masks, interpret=True)
    assert t_stats.routed == d_stats.routed > 0
    assert jax.tree.structure(train_plan) == jax.tree.structure(decode_plan)
