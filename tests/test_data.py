"""Data pipeline: determinism, restartability, learnability signal."""
import numpy as np
import pytest

from repro.data import DataPipeline, SyntheticImages, SyntheticLM


def test_lm_batches_deterministic():
    gen = SyntheticLM(vocab_size=64, seq_len=32, seed=7)
    a = gen.batch(5, 8)
    b = gen.batch(5, 8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = gen.batch(6, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_labels_shifted():
    gen = SyntheticLM(vocab_size=64, seq_len=32, seed=7)
    b = gen.batch(0, 4)
    # labels are next tokens: the markov transition must hold mostly
    T = gen._table()
    pred = T[b["tokens"][:, :-2], b["tokens"][:, 1:-1]]
    agree = (pred == b["labels"][:, 1:-1]).mean()
    assert agree > 0.85          # 1 - noise(0.05) with slack


def test_images_class_structure():
    gen = SyntheticImages(image_size=8, noise=0.1, seed=3)
    b = gen.batch(0, 64)
    t = gen._templates()
    # nearest-template classification recovers labels at low noise
    d = ((b["images"][:, None] - t[None]) ** 2).sum((2, 3, 4))
    assert (d.argmin(1) == b["labels"]).mean() > 0.95


def test_pipeline_restart_reproduces_stream():
    gen = SyntheticLM(vocab_size=64, seq_len=16, seed=1)
    p1 = DataPipeline(lambda s: gen.batch(s, 4), prefetch=0)
    seq1 = [next(p1)["tokens"] for _ in range(5)]
    # restart at step 3 reproduces batches 3,4
    p2 = DataPipeline(lambda s: gen.batch(s, 4), start_step=3, prefetch=0)
    np.testing.assert_array_equal(next(p2)["tokens"], seq1[3])
    np.testing.assert_array_equal(next(p2)["tokens"], seq1[4])


def test_pipeline_prefetch_thread():
    gen = SyntheticLM(vocab_size=32, seq_len=8, seed=2)
    p = DataPipeline(lambda s: gen.batch(s, 2), prefetch=2)
    batches = [next(p) for _ in range(4)]
    p.close()
    ref = [gen.batch(s, 2)["tokens"] for s in range(4)]
    for got, want in zip(batches, ref):
        np.testing.assert_array_equal(got["tokens"], want)
