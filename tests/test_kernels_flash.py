"""Flash attention Pallas kernel vs oracle: shape/dtype/GQA sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import attend, causal_attention


def _qkv(B, S, Hq, Hkv, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, Hq, hd), dtype),
            jax.random.normal(ks[1], (B, S, Hkv, hd), dtype),
            jax.random.normal(ks[2], (B, S, Hkv, hd), dtype))


@pytest.mark.parametrize("S,bq,bk", [(128, 64, 64), (256, 64, 128),
                                     (256, 128, 64)])
@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2), (6, 1)])
def test_flash_causal_matches_oracle(S, bq, bk, Hq, Hkv):
    q, k, v = _qkv(2, S, Hq, Hkv, 32, jnp.float32)
    out = flash_attention(q, k, v, bq=bq, bk=bk)
    ref = causal_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    q, k, v = _qkv(1, 128, 4, 4, 64, dtype)
    out = flash_attention(q, k, v, bq=64, bk=64)
    ref = causal_attention(q, k, v)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_flash_noncausal():
    q, k, v = _qkv(2, 128, 4, 2, 32, jnp.float32, seed=3)
    out = flash_attention(q, k, v, causal=False, bq=64, bk=64)
    ref = attend(q, k, v, causal=False, q_offset=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_long_softmax_stability():
    """Large logits: the online max-rescaling must not overflow."""
    q, k, v = _qkv(1, 128, 2, 2, 16, jnp.float32, seed=7)
    out = flash_attention(q * 30.0, k * 30.0, v, bq=64, bk=64)
    assert np.isfinite(np.asarray(out)).all()
