"""Kernel auditor (K300–K306): seeded-defect tests.

Mirrors tests/test_analysis.py's convention: every K rule code must be
demonstrated by planting the defect it exists to catch and asserting
the auditor reports it; the coverage test at the bottom closes the K
half of the registry (test_analysis.py closes R/P/J, and
test_rules_meta.py asserts the two halves tile the whole registry).
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.analysis import (RULES, audit_kernel_spec, audit_kernels,
                            default_cases, explain, rules_markdown)
from repro.analysis.kernel_audit import audit_case
from repro.kernels import AUDITED_KERNELS, ScratchSpec

TESTED = set()


def codes_of(findings):
    return {f.code for f in findings}


def assert_code(findings, code):
    TESTED.add(code)
    got = codes_of(findings)
    assert code in got, f"expected {code} in {got}: {findings}"


def assert_only(findings, code):
    assert_code(findings, code)
    assert codes_of(findings) == {code}, findings


@pytest.fixture(scope="module")
def cases():
    return {c.name: c for c in default_cases()}


# ---------------------------------------------------------------------------
# the clean path: every registered kernel's canonical case audits green
# ---------------------------------------------------------------------------
def test_registered_kernels_all_audited(cases):
    assert set(cases) == set(AUDITED_KERNELS)


def test_default_cases_audit_clean():
    findings = audit_kernels()
    assert findings == [], findings


def test_audit_is_pure_host_numpy(cases):
    # the audited specs' index maps and guards must evaluate on plain
    # ints/numpy — no tracing, which is what makes the lint gate cheap
    for case in cases.values():
        for f in audit_case(case):
            raise AssertionError(f)


# ---------------------------------------------------------------------------
# K300 — malformed specs are reported, not crashed on
# ---------------------------------------------------------------------------
def test_k300_block_rank_mismatch(cases):
    s = cases["bsmm_fwd"].spec
    x = s.inputs[0]
    bad = dataclasses.replace(
        s, inputs=(dataclasses.replace(x, block=(128,)),) + s.inputs[1:])
    assert_only(audit_kernel_spec(bad), "K300")


def test_k300_uneven_tiling(cases):
    s = cases["bsmm_fwd"].spec
    x = s.inputs[0]
    bad = dataclasses.replace(
        s, inputs=(dataclasses.replace(x, block=(100, 128)),)
        + s.inputs[1:])
    assert_only(audit_kernel_spec(bad), "K300")


def test_k300_raising_index_map(cases):
    s = cases["bsmm_fwd"].spec
    x = s.inputs[0]

    def boom(*a):
        raise RuntimeError("no")

    bad = dataclasses.replace(
        s, inputs=(dataclasses.replace(x, index_map=boom),)
        + s.inputs[1:])
    assert_only(audit_kernel_spec(bad), "K300")


# ---------------------------------------------------------------------------
# K301 — output coverage
# ---------------------------------------------------------------------------
def test_k301_output_map_collapses_tiles(cases):
    # every parallel class writes row 0: rows 1+ never written, row 0
    # written by multiple classes
    s = cases["bsmm_fwd"].spec
    o = s.outputs[0]
    bad = dataclasses.replace(
        s, outputs=(dataclasses.replace(
            o, index_map=lambda i, j, k, cnt, idx: (0, j)),))
    assert_code(audit_kernel_spec(bad), "K301")


def test_k301_output_moves_along_arbitrary_axis(cases):
    # revolving accumulator would flush to a different tile per k step
    s = cases["bsmm_fwd"].spec
    o = s.outputs[0]
    bad = dataclasses.replace(
        s, outputs=(dataclasses.replace(
            o, index_map=lambda i, j, k, cnt, idx: (i, (j + k) % 2)),))
    assert_code(audit_kernel_spec(bad), "K301")


# ---------------------------------------------------------------------------
# K302 — bounds, including guarded cells (their DMA still happens)
# ---------------------------------------------------------------------------
def test_k302_index_map_off_ragged_edge(cases):
    s = cases["bsmm_fwd"].spec
    x = s.inputs[0]
    bad = dataclasses.replace(
        s, inputs=(dataclasses.replace(
            x, index_map=lambda i, j, k, cnt, idx: (i + 1, idx[j, k])),)
        + s.inputs[1:])
    assert_only(audit_kernel_spec(bad), "K302")


def test_k302_block_table_entry_past_pool(cases):
    # a DEAD table slot pointing past the pool: the guarded cell's DMA
    # still prefetches the block, so this must be an error even though
    # pl.when masks the compute
    case = cases["paged_attention_gqa"]
    from repro.kernels.paged_attention import (BLOCK_TOKENS, PagedGeometry,
                                               paged_attention_spec)
    B, Hq, Hkv, hd, P, NB = 2, 4, 2, 8, 5, 3
    tables = np.array([[1, 2, P], [3, 0, 0]], np.int32)   # P == pool size
    lengths = np.array([BLOCK_TOKENS + 2, 7], np.int32)
    geo = PagedGeometry(B=B, Hq=Hq, hd=hd, Hkv=Hkv, T=BLOCK_TOKENS,
                        NB=NB, P=P, dv=hd)
    spec = paged_attention_spec(geo, tables, lengths, fused_v=False)
    findings = audit_kernel_spec(spec,
                                 expected_gathers=case.expected_gathers)
    assert_only(findings, "K302")


# ---------------------------------------------------------------------------
# K303 — guard vs liveness truth, both directions
# ---------------------------------------------------------------------------
def test_k303_loose_guard_streams_dead_blocks(cases):
    # bsmm_dx has dead slots (rows with 1 live tile, nmax 2); widen the
    # guard by one so dead slots' scratch gathers join the accumulation
    case = cases["bsmm_dx"]
    s = case.spec
    hi = s.grid[2]
    bad = dataclasses.replace(
        s, guard=lambda i, k, t, cnt, idx: bool(t <= cnt[k]) and t < hi)
    assert_only(
        audit_kernel_spec(bad, expected_gathers=case.expected_gathers),
        "K303")


def test_k303_tight_guard_drops_live_work(cases):
    case = cases["bsmm_dx"]
    s = case.spec
    bad = dataclasses.replace(
        s, guard=lambda i, k, t, cnt, idx: bool(t + 1 < cnt[k]))
    assert_only(
        audit_kernel_spec(bad, expected_gathers=case.expected_gathers),
        "K303")


# ---------------------------------------------------------------------------
# K304 — accumulator dtype/shape
# ---------------------------------------------------------------------------
def test_k304_f16_accumulator(cases):
    s = cases["bsmm_fwd"].spec
    bad = dataclasses.replace(
        s, scratch=(ScratchSpec(s.scratch[0].shape, np.float16,
                                "accumulator"),))
    assert_only(audit_kernel_spec(bad), "K304")


def test_k304_accumulator_shape_mismatch(cases):
    s = cases["flash_attention"].spec
    acc = s.scratch[0]
    assert acc.role == "accumulator"
    bad = dataclasses.replace(
        s, scratch=(ScratchSpec((acc.shape[0], acc.shape[1] // 2),
                                np.float32, "accumulator"),)
        + s.scratch[1:])
    assert_only(audit_kernel_spec(bad), "K304")


def test_k304_f16_softmax_state(cases):
    s = cases["paged_attention_gqa"].spec
    sm = next(x for x in s.scratch if x.role == "softmax_state")
    scratch = tuple(
        ScratchSpec(x.shape, np.float16, x.role) if x is sm else x
        for x in s.scratch)
    bad = dataclasses.replace(s, scratch=scratch)
    assert_only(audit_kernel_spec(bad), "K304")


# ---------------------------------------------------------------------------
# K305 — VMEM budget
# ---------------------------------------------------------------------------
def test_k305_oversized_block_exceeds_budget(cases):
    # a (2048, 2048) f32 block double-buffers to 32 MiB > the 16 MiB
    # budget; shape stretched so the index maps stay in bounds and the
    # finding is K305 alone
    s = cases["bsmm_fwd"].spec
    x = s.inputs[0]
    bad = dataclasses.replace(
        s, inputs=(dataclasses.replace(x, block=(2048, 2048),
                                       shape=(4096, 6144)),)
        + s.inputs[1:])
    assert_only(audit_kernel_spec(bad), "K305")


def test_k305_respects_backend_budget(cases, monkeypatch):
    from repro.configs import base as base_mod
    monkeypatch.setitem(base_mod.VMEM_BUDGET_BYTES, "tiny_backend", 1024)
    findings = audit_kernel_spec(cases["bsmm_fwd"].spec,
                                 backend="tiny_backend")
    assert_code(findings, "K305")


# ---------------------------------------------------------------------------
# K306 — perf-model agreement
# ---------------------------------------------------------------------------
def test_k306_tampered_cost_detected(cases):
    case = cases["bsmm_fwd"]
    for field in ("passes", "flops", "hbm_bytes"):
        bad = dataclasses.replace(
            case.cost, **{field: getattr(case.cost, field) + 1})
        findings = audit_kernel_spec(
            case.spec, expected_gathers=case.expected_gathers, cost=bad)
        assert_only(findings, "K306")


def test_k306_stale_plan_cost_detected(cases):
    # the signature drift: perf model predicting from a DIFFERENT plan
    # than the kernel launches (e.g. cost computed pre-hot-swap)
    from repro.core.perf_model import bsmm_fwd_cost
    from repro.kernels.bsmm import make_tile_plan
    case = cases["bsmm_fwd"]
    denser = np.ones((3 * 128, 2 * 128), np.float32)
    stale = bsmm_fwd_cost(make_tile_plan(denser, tile=128), 256, bm=128)
    findings = audit_kernel_spec(case.spec, cost=stale)
    assert_only(findings, "K306")


# ---------------------------------------------------------------------------
# registry + CLI surface
# ---------------------------------------------------------------------------
def test_k_rules_registered_and_documented():
    kcodes = {c for c in RULES if c.startswith("K")}
    assert kcodes == {"K300", "K301", "K302", "K303", "K304", "K305",
                      "K306"}
    md = rules_markdown()
    for code in sorted(kcodes):
        assert code in md
        text = explain(code)
        assert RULES[code].title in text and RULES[code].doc in text


def test_explain_unknown_code_raises():
    with pytest.raises(KeyError):
        explain("K999")


def test_cli_lint_kernels_json(capsys):
    from repro.api.cli import main
    assert main(["lint", "--kernels", "--json"]) == 0
    out = json.loads(capsys.readouterr().out.strip())
    assert out["arch"] == "kernels" and out["summary"]["ok"]


def test_cli_lint_kernels_fails_on_defect(monkeypatch, capsys):
    from repro.analysis import Report, error
    from repro.api import cli as cli_mod

    # cmd_lint imports lint_kernels from the package namespace
    monkeypatch.setattr(
        "repro.analysis.lint_kernels",
        lambda backend="tpu": Report(
            findings=[error("K301", "kernels/bsmm_fwd", "seeded")]))
    assert cli_mod.main(["lint", "--kernels", "--json"]) == 1
    out = json.loads(capsys.readouterr().out.strip())
    assert out["findings"][0]["code"] == "K301"


def test_cli_lint_explain(capsys):
    from repro.api.cli import main
    assert main(["lint", "--explain", "k301", "--json"]) == 0
    out = json.loads(capsys.readouterr().out.strip())
    assert out["code"] == "K301" and out["family"] == "kernel auditor"
    assert main(["lint", "--explain", "K999"]) == 2


def test_cli_lint_requires_a_target(capsys):
    from repro.api.cli import main
    assert main(["lint"]) == 2


# keep last: the K half of the registry must be fully exercised above
def test_every_k_rule_code_is_exercised():
    expected = {c for c in RULES if c.startswith("K")}
    assert TESTED == expected, \
        f"untested K rules: {sorted(expected - TESTED)}"
