"""Group scoring + mask zeroing for all granularities."""
import numpy as np
import pytest

from repro.core import scoring
from repro.core.crossbar import conv_to_matrix


def _conv(shape=(3, 3, 8, 16), seed=0):
    return np.random.RandomState(seed).randn(*shape).astype(np.float32)


def test_filter_groups_score_and_zero():
    w = _conv()
    mask = np.ones_like(w)
    gs = scoring.group_scores("p", w, mask, "filter", conv=True)
    assert gs.scores.shape == (1, 16)
    # score of filter oc = mean |w[:,:,:,oc]|
    np.testing.assert_allclose(gs.scores[0, 3],
                               np.abs(w[:, :, :, 3]).mean(), rtol=1e-6)
    kill = np.zeros((1, 16), bool)
    kill[0, 3] = True
    new = scoring.zero_groups(mask, gs, kill)
    assert new[:, :, :, 3].sum() == 0
    assert new.sum() == mask.size - 72


def test_channel_groups_conv():
    w = _conv()
    mask = np.ones_like(w)
    gs = scoring.group_scores("p", w, mask, "channel", conv=True)
    assert gs.scores.shape == (1, 8, 16)
    np.testing.assert_allclose(gs.scores[0, 2, 5],
                               np.abs(w[:, :, 2, 5]).mean(), rtol=1e-6)
    kill = np.zeros((1, 8, 16), bool)
    kill[0, 2, 5] = True
    new = scoring.zero_groups(mask, gs, kill)
    assert new[:, :, 2, 5].sum() == 0
    assert new.sum() == mask.size - 9


def test_index_groups_rowwise():
    w = np.random.RandomState(1).randn(64, 300).astype(np.float32)
    mask = np.ones_like(w)
    gs = scoring.group_scores("p", w, mask, "index", conv=False)
    # 300 cols → 3 col tiles (128,128,44)
    assert gs.scores.shape == (1, 64, 3)
    kill = np.zeros_like(gs.scores, bool)
    kill[0, 10, 2] = True       # row 10 in last (44-wide) tile
    new = scoring.zero_groups(mask, gs, kill)
    assert new[10, 256:].sum() == 0
    assert new[10, :256].sum() == 256


def test_dense_channel_uses_128_row_tiles():
    w = np.random.RandomState(2).randn(300, 64).astype(np.float32)
    mask = np.ones_like(w)
    gs = scoring.group_scores("p", w, mask, "channel", conv=False)
    assert gs.scores.shape == (1, 3, 64)
    kill = np.zeros_like(gs.scores, bool)
    kill[0, 0, 7] = True
    new = scoring.zero_groups(mask, gs, kill)
    assert new[:128, 7].sum() == 0 and new[128:, 7].all()


def test_select_global_prune_hits_fraction():
    np.random.seed(3)
    sets = []
    leaves = {}
    for i, shape in enumerate([(64, 128), (128, 256)]):
        w = np.random.randn(*shape).astype(np.float32)
        m = np.ones_like(w)
        leaves[f"l{i}"] = (w, m)
        sets.append(scoring.group_scores(f"l{i}", w, m, "ltp", conv=False))
    remaining = sum(m.size for (_, m) in leaves.values())
    kills = scoring.select_global_prune(sets, 0.25, remaining)
    killed = sum(k.sum() for k in kills.values())
    assert abs(killed / remaining - 0.25) < 0.01


def test_scores_ignore_dead_groups():
    w = _conv()
    mask = np.ones_like(w)
    gs = scoring.group_scores("p", w, mask, "filter", conv=True)
    kill = np.zeros((1, 16), bool)
    kill[0, :8] = True
    m2 = scoring.zero_groups(mask, gs, kill)
    gs2 = scoring.group_scores("p", w, m2, "filter", conv=True)
    assert (~gs2.alive[0, :8]).all() and gs2.alive[0, 8:].all()
