"""Pallas block-sparse matmul vs the pure-jnp oracle: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bsmm import (bsmm_pallas, compact_tile_indices,
                                make_tile_plan, masked_matmul_pallas,
                                plan_matmul)
from repro.kernels.ops import sparse_dense, tile_bitmap, tile_density
from repro.kernels.ref import bsmm_ref, expand_tile_mask, masked_matmul_ref

SHAPES = [
    (128, 128, 128, 128),
    (256, 384, 256, 128),
    (128, 256, 512, 128),
    (256, 256, 256, 64),        # smaller tiles
    (512, 128, 128, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-1) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("M,K,N,b", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("density", [0.0, 0.3, 1.0])
def test_bsmm_matches_oracle(M, K, N, b, dtype, density):
    rng = np.random.RandomState(hash((M, K, N, b)) % 2**31)
    x = jnp.asarray(rng.randn(M, K), dtype)
    w = jnp.asarray(rng.randn(K, N), dtype)
    tm = (rng.rand(K // b, N // b) < density).astype(np.int32)
    out = bsmm_pallas(x, w, tm, bm=b, bk=b, bn=b, interpret=True)
    ref = bsmm_ref(x, w, tm, b, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_matmul_matches_oracle(dtype):
    rng = np.random.RandomState(7)
    M = K = N = 256
    x = jnp.asarray(rng.randn(M, K), dtype)
    w = jnp.asarray(rng.randn(K, N), dtype)
    mask = (rng.rand(K, N) > 0.5).astype(np.float32)
    out = masked_matmul_pallas(x, w, jnp.asarray(mask), interpret=True)
    ref = masked_matmul_ref(x, w, jnp.asarray(mask, dtype))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_compact_indices_roundtrip():
    rng = np.random.RandomState(3)
    tm = (rng.rand(7, 5) > 0.6).astype(np.int32)
    idx, counts, kmax = compact_tile_indices(tm)
    assert kmax == max(1, counts.max())
    for j in range(5):
        live = set(np.nonzero(tm[:, j])[0].tolist())
        assert set(idx[j, :counts[j]].tolist()) == live


def test_sparse_dense_wrapper_fallback_and_tiled():
    rng = np.random.RandomState(5)
    w = rng.randn(384, 256).astype(np.float32)
    mask = np.ones_like(w)
    mask[:128] = 0
    # tiled path (leading dims folded)
    x = jnp.asarray(rng.randn(2, 64, 384), jnp.float32)
    out = sparse_dense(x, jnp.asarray(w), mask)
    ref = masked_matmul_ref(x.reshape(-1, 384), jnp.asarray(w),
                            jnp.asarray(mask)).reshape(2, 64, 256)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)
    # ragged fallback (non-tiling K)
    x2 = jnp.asarray(rng.randn(3, 100), jnp.float32)
    w2 = jnp.asarray(rng.randn(100, 60), jnp.float32)
    m2 = (rng.rand(100, 60) > 0.3).astype(np.float32)
    out2 = sparse_dense(x2, w2, m2)
    np.testing.assert_allclose(np.asarray(out2),
                               np.asarray(masked_matmul_ref(x2, w2,
                                                            jnp.asarray(m2))),
                               rtol=1e-5, atol=1e-4)


def test_tile_density_accounting():
    mask = np.ones((256, 256), np.float32)
    mask[:128, :128] = 0
    assert tile_density(mask) == 0.75
    bm = tile_bitmap(mask)
    assert bm.shape == (2, 2) and bm[0, 0] == 0 and bm.sum() == 3


def test_compact_indices_all_dead_column():
    """A fully-dead output column gets count 0 and placeholder indices
    that still point at a valid DMA target (tile 0)."""
    tm = np.ones((4, 3), np.int32)
    tm[:, 1] = 0
    idx, counts, kmax = compact_tile_indices(tm)
    assert counts.tolist() == [4, 0, 4]
    assert kmax == 4
    assert idx[1].tolist() == [0, 0, 0, 0]      # masked in-kernel


def test_compact_indices_all_dead_mask_still_one_pass():
    idx, counts, kmax = compact_tile_indices(np.zeros((5, 4), np.int32))
    assert kmax == 1                    # grid dim must stay >= 1
    assert counts.tolist() == [0, 0, 0, 0]


def test_compact_indices_empty_mask():
    idx, counts, kmax = compact_tile_indices(np.zeros((0, 0), np.int32))
    assert counts.shape == (0,) and kmax == 1 and idx.shape == (0, 1)
    idx, counts, kmax = compact_tile_indices(np.zeros((3, 0), np.int32))
    assert counts.shape == (0,) and idx.shape == (0, 1)


def test_bsmm_rejects_non_tiling_last_tile():
    """K/N that leave a ragged (non-128-multiple) last tile must be
    rejected, not silently mis-indexed."""
    from repro.kernels.bsmm import GeometryError
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(128, 200), jnp.float32)     # K = 200
    w = jnp.asarray(rng.randn(200, 128), jnp.float32)
    with pytest.raises(GeometryError, match="tile") as ei:
        bsmm_pallas(x, w, np.ones((2, 1), np.int32), interpret=True)
    assert ei.value.shape == (128, 200, 128)      # structured context
    with pytest.raises(GeometryError):
        bsmm_pallas(jnp.asarray(rng.randn(100, 128), jnp.float32),
                    jnp.asarray(rng.randn(128, 128), jnp.float32),
                    np.ones((1, 1), np.int32), interpret=True)


def test_make_tile_plan_eligibility():
    assert make_tile_plan(np.ones((128, 200))) is None    # ragged N
    assert make_tile_plan(np.ones((100, 128))) is None    # ragged K
    assert make_tile_plan(np.ones((2, 128, 128))) is None  # not 2-D
    plan = make_tile_plan(np.ones((256, 128)))
    assert plan is not None
    assert (plan.live_tiles, plan.total_tiles) == (2, 2)


def test_plan_matmul_matches_dense_with_row_padding():
    """Tiny-M decode batches (padded to a sublane multiple) and dead
    tiles: plan_matmul == dense on pre-masked weights."""
    rng = np.random.RandomState(1)
    mask = np.ones((256, 128), np.float32)
    mask[:128] = 0.0                    # kill the first K tile
    w = jnp.asarray(rng.randn(256, 128) * mask, jnp.float32)
    plan = make_tile_plan(mask)
    assert plan.live_tiles == 1
    for lead in [(4,), (3, 1), (2, 64)]:
        x = jnp.asarray(rng.randn(*lead, 256), jnp.float32)
        np.testing.assert_allclose(np.asarray(plan_matmul(x, w, plan)),
                                   np.asarray(x @ w),
                                   rtol=1e-5, atol=1e-4)
    # plan=None is the dense path
    x = jnp.asarray(rng.randn(4, 256), jnp.float32)
    np.testing.assert_allclose(np.asarray(plan_matmul(x, w, None)),
                               np.asarray(x @ w), rtol=1e-6, atol=1e-5)


def test_grid_skips_match_savings():
    """The kernel's K-grid length equals the max live tiles per column —
    the compute saving the paper's crossbar savings maps to."""
    tm = np.zeros((8, 4), np.int32)
    tm[:2, 0] = 1
    tm[:5, 1] = 1
    idx, counts, kmax = compact_tile_indices(tm)
    assert kmax == 5                      # not 8: 3/8 of passes skipped
    assert counts.tolist() == [2, 5, 0, 0]
