"""Continuous-batching scheduler: pad-correct prefill (batch
invariance), mid-decode slot refill vs static grouping, capacity guard,
bsmm-backed decode, and the throughput report."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import structured_prune
from repro.configs import PruneConfig, get_arch, scaled_down
from repro.core.masks import apply_masks, lm_prunable
from repro.models import transformer as tfm
from repro.serve import Request, ServeEngine

CAP = 96


@pytest.fixture(scope="module")
def setup():
    cfg = scaled_down(get_arch("llama3.2-3b"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, slots=4, **kw):
    return ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                       decode_fn=tfm.decode_step, batch_slots=slots,
                       capacity=CAP, **kw)


def _run(cfg, params, reqs, slots=4, **kw):
    eng = _engine(cfg, params, slots=slots, **kw)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(r.done for r in done)
    return {r.uid: r.tokens for r in done}, eng


# ---------------------------------------------------------------------------
# pad correctness / batch invariance (the left-pad contamination bugfix)
# ---------------------------------------------------------------------------
def test_request_tokens_are_batch_invariant(setup):
    """A request decoded alone and decoded alongside a longer prompt
    emits identical tokens — padding must never act as real context."""
    cfg, params = setup
    short = np.arange(1, 7, dtype=np.int32)
    long = np.arange(3, 27, dtype=np.int32)
    alone, _ = _run(cfg, params,
                    [Request(uid=1, prompt=short.copy(), max_new_tokens=8)])
    mixed, _ = _run(cfg, params,
                    [Request(uid=0, prompt=long.copy(), max_new_tokens=8),
                     Request(uid=1, prompt=short.copy(), max_new_tokens=8)])
    assert alone[1] == mixed[1]


def test_batched_greedy_matches_autoregressive_forward(setup):
    """Greedy decode in a mixed batch == token-by-token full forward."""
    cfg, params = setup
    prompts = [np.arange(1, 7, dtype=np.int32),
               np.arange(3, 27, dtype=np.int32)]
    got, _ = _run(cfg, params,
                  [Request(uid=i, prompt=p.copy(), max_new_tokens=5)
                   for i, p in enumerate(prompts)])

    for i, p in enumerate(prompts):
        toks, ctx = [], list(p)
        for _ in range(5):
            lg, _ = tfm.forward(
                params, cfg,
                {"tokens": jnp.asarray(np.asarray(ctx, np.int32)[None])})
            nxt = int(jnp.argmax(lg[0, -1]))
            toks.append(nxt)
            ctx.append(nxt)
        assert got[i] == toks


def test_masked_prefill_matches_exact_prefill(setup):
    """Right-padded prefill with valid_len reproduces the unpadded
    last-position logits (the model-level half of the pad fix)."""
    cfg, params = setup
    prompt = np.arange(1, 8, dtype=np.int32)
    padded = np.zeros((1, 16), np.int32)
    padded[0, :7] = prompt
    lg_m, caches = tfm.prefill(params, cfg, {"tokens": jnp.asarray(padded)},
                               32, valid_len=jnp.asarray([7]))
    lg_e, _ = tfm.prefill(params, cfg,
                          {"tokens": jnp.asarray(prompt[None])}, 32)
    np.testing.assert_allclose(np.asarray(lg_m), np.asarray(lg_e),
                               rtol=1e-5, atol=1e-5)


def test_supports_masked_prefill_flags():
    assert tfm.supports_masked_prefill(
        scaled_down(get_arch("llama3.2-3b")))
    # recurrent blocks carry state through padding → exact-length only
    assert not tfm.supports_masked_prefill(
        scaled_down(get_arch("recurrentgemma-2b")))
    # MoE expert capacity is computed over padded positions too
    assert not tfm.supports_masked_prefill(
        scaled_down(get_arch("deepseek-v3-671b")))


# ---------------------------------------------------------------------------
# slot refill vs static group-at-a-time batching
# ---------------------------------------------------------------------------
def test_refill_beats_static_grouping_with_identical_outputs(setup):
    """Mixed budgets: the refilling scheduler finishes in strictly fewer
    decode steps than static grouping (each group stalls on its slowest
    member: sum of per-group max budgets), with identical tokens."""
    cfg, params = setup
    budgets = [9, 2, 9, 2]
    slots = 2
    mk = lambda: [Request(uid=i, prompt=np.arange(1 + i, 9 + i,
                                                  dtype=np.int32),
                          max_new_tokens=b)
                  for i, b in enumerate(budgets)]
    got, eng = _run(cfg, params, mk(), slots=slots)
    # static grouping: groups [9,2],[9,2] → (9-1) + (9-1) decode steps
    static_steps = sum(
        max(budgets[i:i + slots]) - 1
        for i in range(0, len(budgets), slots))
    assert eng.report.decode_steps < static_steps
    assert all(len(got[i]) == b for i, b in enumerate(budgets))

    # identical per-request outputs vs serving each request by itself
    for req in mk():
        solo, _ = _run(cfg, params, [req], slots=slots)
        assert solo[req.uid] == got[req.uid]


def test_more_requests_than_slots_all_complete(setup):
    cfg, params = setup
    got, eng = _run(cfg, params,
                    [Request(uid=i,
                             prompt=np.arange(1, 5 + i % 7, dtype=np.int32),
                             max_new_tokens=2 + i % 5)
                     for i in range(9)], slots=3)
    assert len(got) == 9
    assert all(len(got[i]) == 2 + i % 5 for i in range(9))


# ---------------------------------------------------------------------------
# capacity guard
# ---------------------------------------------------------------------------
def test_oversized_request_rejected(setup):
    cfg, params = setup
    # dense caches: the static capacity limit still applies
    eng = _engine(cfg, params, paged=False)
    with pytest.raises(ValueError, match="capacity"):
        eng.submit(Request(uid=0,
                           prompt=np.arange(CAP - 3, dtype=np.int32),
                           max_new_tokens=4))
    # right at the boundary is fine
    eng.submit(Request(uid=1, prompt=np.arange(CAP - 4, dtype=np.int32),
                       max_new_tokens=4))
    # paged KV: the same request is admissible (limit is max_context),
    # but a request beyond (kv_blocks - 1) * BLOCK is still rejected
    eng = _engine(cfg, params)
    assert eng.paged and eng.max_context > CAP
    eng.submit(Request(uid=2, prompt=np.arange(CAP - 3, dtype=np.int32),
                       max_new_tokens=4))
    with pytest.raises(ValueError, match="paged KV limit"):
        eng.submit(Request(uid=3,
                           prompt=np.arange(eng.max_context,
                                            dtype=np.int32) % 100,
                           max_new_tokens=4))


def test_degenerate_requests_rejected(setup):
    cfg, params = setup
    eng = _engine(cfg, params)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(uid=0, prompt=np.zeros((0,), np.int32)))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=0))


# ---------------------------------------------------------------------------
# throughput report
# ---------------------------------------------------------------------------
def test_throughput_report_fields(setup):
    cfg, params = setup
    got, eng = _run(cfg, params,
                    [Request(uid=i, prompt=np.arange(1, 9, dtype=np.int32),
                             max_new_tokens=4) for i in range(5)], slots=2)
    rep = eng.report
    assert rep.requests == 5
    assert rep.prefills == 5
    assert rep.tokens_generated == sum(len(t) for t in got.values()) == 20
    assert rep.decode_steps > 0
    assert 0.0 < rep.slot_occupancy <= 1.0
    assert rep.wall_s > 0 and rep.tokens_per_s > 0
    assert not rep.bsmm_enabled
    assert rep.skipped_tile_fraction == 0.0


def test_empty_run_reports_zero(setup):
    cfg, params = setup
    eng = _engine(cfg, params)
    assert eng.run() == []
    assert eng.report.requests == 0
    assert eng.report.decode_steps == 0


# ---------------------------------------------------------------------------
# bsmm-backed decode for pruned tickets
# ---------------------------------------------------------------------------
def test_bsmm_decode_matches_dense_and_reports_tiles(setup):
    cfg, params = setup
    masks = structured_prune(params, [("filter", 0.3)],
                             prunable=lm_prunable, cfg=PruneConfig())
    pm = apply_masks(params, masks)
    mk = lambda: [Request(uid=i,
                          prompt=np.arange(1 + i, 9 + i, dtype=np.int32),
                          max_new_tokens=5) for i in range(3)]
    dense, _ = _run(cfg, pm, mk(), slots=2)
    sparse, eng = _run(cfg, pm, mk(), slots=2, masks=masks)
    assert dense == sparse
    rep = eng.report
    assert rep.bsmm_enabled
    assert rep.routed_matmuls > 0
    assert rep.total_tiles >= rep.live_tiles > 0
    assert 0.0 <= rep.skipped_tile_fraction < 1.0


def test_use_bsmm_flags(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="use_bsmm"):
        _engine(cfg, params, use_bsmm=True)          # no masks
    masks = structured_prune(params, [("filter", 0.2)],
                             prunable=lm_prunable, cfg=PruneConfig())
    eng = _engine(cfg, params, masks=masks, use_bsmm=False)  # forced off
    eng.submit(Request(uid=0, prompt=np.arange(1, 7, dtype=np.int32),
                       max_new_tokens=3))
    eng.run()
    assert not eng.report.bsmm_enabled
