"""CNN models: shapes, residual wiring, BN state, training signal."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_cnn, list_cnns
from repro.data import SyntheticImages
from repro.models import cnn as cnn_lib
from repro.optim import constant, sgd


@pytest.mark.parametrize("name", list_cnns())
def test_forward_shapes_and_finite(name):
    cfg = get_cnn(name)
    params, state = cnn_lib.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits, new_state = cnn_lib.forward(params, state, cfg, x, train=True)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()
    # BN running stats updated in train mode
    changed = any(
        not np.allclose(np.asarray(a["mean"]), np.asarray(b["mean"]))
        for a, b in zip(state["bns"], new_state["bns"]))
    assert changed


def test_resnet18_has_projection_shortcuts():
    cfg = get_cnn("resnet18")
    params, state = cnn_lib.init_params(jax.random.PRNGKey(0), cfg)
    # stride-2 stage transitions at convs 5, 9, 13
    assert set(params["shortcuts"].keys()) == {"5", "9", "13"}


def test_eval_mode_uses_running_stats():
    cfg = get_cnn("vgg11")
    params, state = cnn_lib.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    l1, st1 = cnn_lib.forward(params, state, cfg, x, train=False)
    l2, st2 = cnn_lib.forward(params, state, cfg, x, train=False)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    for a, b in zip(state["bns"], st1["bns"]):
        np.testing.assert_array_equal(np.asarray(a["mean"]),
                                      np.asarray(b["mean"]))


def test_small_cnn_learns_synthetic_task():
    from repro.configs import CNNConfig, ConvSpec
    cfg = CNNConfig(name="t", family="cnn",
                    convs=(ConvSpec(8, pool=True), ConvSpec(16, pool=True)),
                    fc=(), num_classes=10, image_size=16)
    data = SyntheticImages(image_size=16, noise=0.2)
    params, state = cnn_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd(constant(0.05), momentum=0.9)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, state, batch):
        def lf(p):
            loss, (nst, _) = cnn_lib.loss_fn(p, state, cfg, batch, True)
            return loss, nst
        (loss, nst), g = jax.value_and_grad(lf, has_aux=True)(params)
        params, opt_state = opt.update(g, opt_state, params)
        return params, opt_state, nst, loss

    for i in range(60):
        b = data.batch(i, 64)
        params, opt_state, state, loss = step(
            params, opt_state, state,
            {"images": jnp.asarray(b["images"]),
             "labels": jnp.asarray(b["labels"])})
    b = data.batch(999, 256)
    acc = float(cnn_lib.accuracy(params, state, cfg,
                                 jnp.asarray(b["images"]),
                                 jnp.asarray(b["labels"])))
    assert acc > 0.8
