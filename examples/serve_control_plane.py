"""Serving control-plane demo: streaming, deadlines, zero-drain swap.

    PYTHONPATH=src python examples/serve_control_plane.py [--arch yi-6b]

The full lifecycle on one tiny model:

1. Prune TWO tickets at different rates and export them (they embed
   the recipe + arch metadata the ticket manager verifies).
2. Register both with ``TicketManager`` — each gets an accuracy
   fingerprint (greedy smoke-decode of a fixed probe).
3. Serve streaming requests through ``ServeFrontend`` (per-token
   callbacks, bounded admission queue, one request with a deadline).
4. Mid-decode, hot-swap ticket B into the live engine: in-flight
   requests finish bit-identical to a no-swap oracle, and the next
   admitted request decodes under B's tile plans — the skipped-tile
   fraction shift is printed as proof.
"""
import argparse
import sys
import tempfile
sys.path.insert(0, "src")

import numpy as np

from repro.api import structured_prune
from repro.api.registry import make_adapter
from repro.configs import PruneConfig
from repro.core import lottery
from repro.serve import Request, ServeFrontend, TicketManager


def export_ticket(adapter, params, stages, path):
    masks = structured_prune(params, stages, prunable=adapter.prunable,
                             cfg=PruneConfig())
    lottery.export_ticket(path, lottery.snapshot(params), masks,
                          meta={"arch": adapter.cfg.name,
                                "recipe": {"name": "demo"},
                                "quantize_bits": None})
    return masks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    import jax
    adapter = make_adapter(args.arch, scale="tiny")
    params = adapter.init_params(jax.random.PRNGKey(0))
    tmp = tempfile.mkdtemp(prefix="tickets-")
    export_ticket(adapter, params, [("filter", 0.2)], f"{tmp}/a")
    export_ticket(adapter, params, [("xbar", 0.4), ("filter", 0.3)],
                  f"{tmp}/b")

    manager = TicketManager.from_adapter(adapter)
    rec_a = manager.register("a", f"{tmp}/a")
    rec_b = manager.register("b", f"{tmp}/b")
    print(f"registered tickets: a (fp={rec_a.fingerprint[:3]}...), "
          f"b (fp={rec_b.fingerprint[:3]}...)")

    mk = lambda: [Request(uid=i,
                          prompt=np.arange(1 + i, 9 + i, dtype=np.int32),
                          max_new_tokens=args.max_new) for i in range(3)]

    # oracle: the same traffic served entirely on ticket A
    oracle_eng = manager.make_engine("a", batch_slots=4, capacity=96)
    for r in mk():
        oracle_eng.submit(r)
    oracle = {r.uid: list(r.tokens) for r in oracle_eng.run()}
    skip_a = oracle_eng.report.skipped_tile_fraction

    # live: same traffic, streaming, swap to B mid-decode
    engine = manager.make_engine("a", batch_slots=4, capacity=96)
    frontend = ServeFrontend(engine)
    for r in mk():
        r.on_token = (lambda uid: lambda t:
                      print(f"  stream uid={uid}: {t}"))(r.uid)
        frontend.submit(request=r)
    frontend.pump(3)                       # requests now mid-decode
    ev = manager.swap(frontend, "b")
    print(f"swap(b): accepted={ev.accepted} gen={ev.gid} "
          f"skipped tiles {skip_a:.0%} -> {ev.skipped_tile_fraction:.0%}")

    # a post-swap admission (with a deadline) decodes under B's plans
    probe = frontend.submit(np.arange(2, 10, dtype=np.int32), uid=99,
                            max_new_tokens=args.max_new, deadline_s=60.0)
    frontend.drain()

    done = {r.uid: r for r in frontend.finished}
    match = all(done[u].tokens == oracle[u] for u in oracle)
    print(f"in-flight outputs bit-identical to no-swap oracle: {match}")
    print(f"probe request served on generation "
          f"{probe.request.generation} (ticket b)")
    rep = engine.report
    print(f"report: {rep.requests} requests | ttft p50 "
          f"{rep.ttft_p50 * 1e3:.1f}ms | tok/s p50 {rep.tps_p50:.1f} | "
          f"deadline misses {rep.deadline_misses} | swaps {rep.swaps}")
    if not (match and ev.accepted and probe.request.generation == ev.gid):
        raise SystemExit("zero-drain hot-swap demo FAILED")
    print("zero-drain hot-swap demo OK")


if __name__ == "__main__":
    main()
