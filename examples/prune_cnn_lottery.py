"""Paper pipeline at configurable scale: ReaLPrune a ResNet-18-family
CNN on CIFAR-like data, export the winning ticket, and verify the
ticket trains from scratch with no accuracy loss (paper §V.B) — all
through the ``repro.api`` session layer.

    PYTHONPATH=src python examples/prune_cnn_lottery.py [--full]

Default: the resnet18 config scaled down by the family registry
(``make_adapter(..., scale="tiny")`` — same block structure, capped
channels) for CPU minutes.  ``--full``: the real resnet18 config
(hours on CPU; the masks/savings pipeline is identical), which also
picks up the family's TUNED staged recipe (``cnn-full``: paper
schedule + int8 QAT) from the registry.  ``--recipe`` overrides with
any registered recipe name or a recipe .json path.

CLI parity — the same run from the shell:

    python -m repro.api prune --arch resnet18 --scale tiny \
        --recipe paper-quant --rounds 10 --ticket /tmp/realprune_ticket
"""
import argparse
import sys
sys.path.insert(0, "src")

from repro.api import PruningSession, make_adapter
from repro.configs import PruneConfig
from repro.core import lottery
from repro.core.hardware import cnn_activation_volumes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--arch", default="resnet18")
    ap.add_argument("--steps", type=int, default=None,
                    help="retrain steps per round (default 120; when "
                         "set explicitly it also overrides the "
                         "recipe's per-stage budgets)")
    ap.add_argument("--recipe", default=None,
                    help="staged prune program (name from `python -m "
                         "repro.api recipes` or a .json path); default: "
                         "the family schedule at --scale tiny, the tuned "
                         "cnn-full recipe at --full")
    ap.add_argument("--ticket-dir", default="/tmp/realprune_ticket")
    ap.add_argument("--ckpt", default=None,
                    help="session checkpoint dir (resume a killed run)")
    args = ap.parse_args()

    # the family registry picks the adapter class, prunability
    # predicates, and prune recipe/schedule for us — this script works
    # for ANY registered CNN (and, family aside, any arch at all)
    adapter = make_adapter(
        args.arch, scale="full" if args.full else "tiny",
        steps=args.steps or 120, batch_size=128,     # paper: batch size 128
        lr=0.1, lr_decay=0.95,                       # paper: LR .1, -5%/epoch
        eval_batches=4, eval_batch_size=256)
    cfg = adapter.cfg

    print(f"== ReaLPrune lottery pipeline: {cfg.name} ==")
    session = PruningSession(
        adapter, PruneConfig(prune_fraction=0.25, max_iters=10,
                             accuracy_tolerance=0.02),
        recipe=args.recipe, ckpt_dir=args.ckpt)
    if args.steps:
        # an explicit --steps wins over per-stage retrain budgets,
        # whether the recipe came from --recipe or the family registry
        session.recipe = session.recipe.with_retrain_steps(args.steps)
    print(f"recipe: {session.recipe.name} "
          f"({' -> '.join(s.name for s in session.recipe.stages)})")
    res = session.run()
    print(f"winning-ticket sparsity: {res.sparsity:.3f}"
          + (f" (int{session.quantize_bits} QAT accepted)"
             if session.quantize_bits else ""))

    # export/import the ticket (paper §V.C: prune once, reuse forever)
    session.export_ticket(args.ticket_dir)
    w_back, m_back = lottery.import_ticket(args.ticket_dir,
                                           session.init_params, res.masks)
    print(f"ticket exported to {args.ticket_dir} and re-imported")

    # train the ticket from scratch — no accuracy loss vs baseline
    baseline_params = adapter.train(session.init_params, None)
    base_acc = adapter.evaluate(baseline_params)
    ticket_params = adapter.train(lottery.rewind(w_back, m_back), m_back)
    ticket_acc = adapter.evaluate(ticket_params, m_back)
    print(f"baseline acc {base_acc:.3f} | ticket acc {ticket_acc:.3f} "
          f"(sparsity {res.sparsity:.1%})")

    rep = session.hardware_report(
        activation_volumes=cnn_activation_volumes(cfg))
    print(f"hardware: cell savings {rep.cell_savings:.1%}, "
          f"crossbars {rep.xbars_needed}/{rep.xbars_unpruned} "
          f"(-{rep.xbar_savings:.1%})")


if __name__ == "__main__":
    main()
