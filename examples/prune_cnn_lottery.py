"""Paper pipeline at configurable scale: ReaLPrune a ResNet-18-family
CNN on CIFAR-like data, export the winning ticket, and verify the
ticket trains from scratch with no accuracy loss (paper §V.B).

    PYTHONPATH=src python examples/prune_cnn_lottery.py [--full]

Default: a reduced ResNet (same block structure) for CPU minutes.
``--full``: the real resnet18 config (hours on CPU; the masks/savings
pipeline is identical).
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CNNConfig, ConvSpec, PruneConfig, get_cnn
from repro.core import algorithm as alg
from repro.core import lottery
from repro.core.hardware import analyze_masks, cnn_activation_volumes
from repro.core.masks import apply_masks, cnn_prunable
from repro.data import SyntheticImages
from repro.models import cnn as cnn_lib
from repro.optim import exponential_epoch_decay, masked, sgd

CONV_PRED = lambda p: "convs" in p or "shortcuts" in p  # noqa: E731

MINI_RESNET = CNNConfig(
    name="mini-resnet", family="cnn",
    convs=(
        ConvSpec(16),
        ConvSpec(16, residual=True), ConvSpec(16),
        ConvSpec(32, stride=2, residual=True), ConvSpec(32),
        ConvSpec(64, stride=2, residual=True), ConvSpec(64),
    ),
    fc=(), num_classes=10, image_size=32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ticket-dir", default="/tmp/realprune_ticket")
    args = ap.parse_args()

    cfg = get_cnn("resnet18") if args.full else MINI_RESNET
    data = SyntheticImages(image_size=cfg.image_size, noise=0.25)
    rng = jax.random.PRNGKey(0)
    params0, bn0 = cnn_lib.init_params(rng, cfg)
    holder = {"bn": bn0}

    def train_fn(params, masks):
        opt = masked(sgd(exponential_epoch_decay(
            0.1, 0.95, args.steps // 2)), masks)   # paper: LR .1, -5%/epoch
        opt_state = opt.init(params)
        state, params = bn0, apply_masks(params, masks)

        @jax.jit
        def step(params, opt_state, state, batch):
            def lf(p):
                loss, (nst, _) = cnn_lib.loss_fn(p, state, cfg, batch, True)
                return loss, nst
            (loss, nst), g = jax.value_and_grad(lf, has_aux=True)(params)
            params, opt_state = opt.update(g, opt_state, params)
            return params, opt_state, nst, loss

        for i in range(args.steps):
            b = data.batch(i, 128)                 # paper: batch size 128
            params, opt_state, state, _ = step(
                params, opt_state, state,
                {"images": jnp.asarray(b["images"]),
                 "labels": jnp.asarray(b["labels"])})
        holder["bn"] = state
        return params

    def eval_fn(params, masks):
        accs = [float(cnn_lib.accuracy(
            params, holder["bn"], cfg,
            jnp.asarray(data.batch(10_000 + i, 256)["images"]),
            jnp.asarray(data.batch(10_000 + i, 256)["labels"])))
            for i in range(4)]
        return float(np.mean(accs))

    print(f"== ReaLPrune lottery pipeline: {cfg.name} ==")
    res = alg.realprune(
        init_params=params0, train_fn=train_fn, eval_fn=eval_fn,
        prunable=cnn_prunable, conv_pred=CONV_PRED,
        cfg=PruneConfig(prune_fraction=0.25, max_iters=10,
                        accuracy_tolerance=0.02))
    print(f"winning-ticket sparsity: {res.sparsity:.3f}")

    # export/import the ticket (paper §V.C: prune once, reuse forever)
    w0 = lottery.snapshot(params0)
    lottery.export_ticket(args.ticket_dir, w0, res.masks)
    w_back, m_back = lottery.import_ticket(args.ticket_dir, params0,
                                           res.masks)
    print(f"ticket exported to {args.ticket_dir} and re-imported")

    # train the ticket from scratch — no accuracy loss vs baseline
    baseline_params = train_fn(params0,
                               jax.tree.map(lambda x: None, res.masks,
                                            is_leaf=lambda x: x is None))
    base_acc = eval_fn(baseline_params, None)
    ticket_params = train_fn(lottery.rewind(w_back, m_back), m_back)
    ticket_acc = eval_fn(ticket_params, m_back)
    print(f"baseline acc {base_acc:.3f} | ticket acc {ticket_acc:.3f} "
          f"(sparsity {res.sparsity:.1%})")

    rep = analyze_masks(res.masks, CONV_PRED,
                        activation_volumes=cnn_activation_volumes(cfg))
    print(f"hardware: cell savings {rep.cell_savings:.1%}, "
          f"crossbars {rep.xbars_needed}/{rep.xbars_unpruned} "
          f"(-{rep.xbar_savings:.1%})")


if __name__ == "__main__":
    main()
