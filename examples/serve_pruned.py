"""Batched serving demo: ServeEngine over a pruned (ticket) LM.

    PYTHONPATH=src python examples/serve_pruned.py [--arch yi-6b]

Builds a reduced config of the chosen architecture, prunes it
crossbar-aware, and serves a queue of batched requests through
prefill + decode with KV caches.
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_arch, scaled_down
from repro.core import algorithm as alg
from repro.core.masks import apply_masks, lm_prunable, make_masks, \
    sparsity_fraction
from repro.models import transformer as tfm
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = scaled_down(get_arch(args.arch), dtype="float32")
    rng = jax.random.PRNGKey(0)
    params = tfm.init_params(rng, cfg)

    # prune the serving weights (tile/crossbar-aware)
    masks = make_masks(params, lm_prunable)
    masks = alg.prune_step(params, masks, "filter", 0.2, lambda p: False)
    masks = alg.prune_step(params, masks, "index", 0.2, lambda p: False)
    params = apply_masks(params, masks)
    print(f"serving {cfg.name} at {sparsity_fraction(masks):.1%} sparsity")

    engine = ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                         decode_fn=tfm.decode_step, batch_slots=4,
                         capacity=128)
    rng_np = np.random.RandomState(0)
    for i in range(args.requests):
        prompt = rng_np.randint(0, 200, size=rng_np.randint(4, 24))
        engine.submit(Request(uid=i, prompt=prompt.astype(np.int32),
                              max_new_tokens=args.max_new))
    done = engine.run()
    for r in sorted(done, key=lambda r: r.uid)[:6]:
        print(f"req {r.uid:02d}: prompt[{len(r.prompt):2d} toks] → "
              f"{r.tokens}")
    print(f"served {len(done)} requests in batches of ≤4")


if __name__ == "__main__":
    main()
