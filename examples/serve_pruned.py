"""Continuous-batching serving demo: a pruned (ticket) LM behind
``ServeEngine`` with block-sparse decode.

    PYTHONPATH=src python examples/serve_pruned.py [--arch yi-6b] \
        [--temperature 0.8] [--no-bsmm]

Builds a reduced config of the chosen architecture, prunes it
crossbar-aware through ``repro.api.structured_prune``, and serves a
queue of mixed-length, mixed-budget requests.  The engine prefills each
request padded to a length bucket (masked, so padding never contaminates
attention), refills slots mid-decode the moment a request finishes, and
routes the decode projections through the bsmm Pallas kernel using the
tile bitmap derived from the ticket's masks — then prints the
throughput report (tokens/s, slot occupancy, skipped-tile fraction).
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import numpy as np

from repro.api import LMAdapter, structured_prune
from repro.configs import PruneConfig, get_arch, scaled_down
from repro.core.masks import apply_masks, lm_prunable, sparsity_fraction
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 = temperature sampling")
    ap.add_argument("--sample-seed", type=int, default=0)
    ap.add_argument("--no-bsmm", action="store_true",
                    help="decode dense even though masks are available")
    args = ap.parse_args()

    cfg = scaled_down(get_arch(args.arch), dtype="float32")
    adapter = LMAdapter(cfg)
    params = adapter.init_params(jax.random.PRNGKey(0))

    # prune the serving weights (tile/crossbar-aware)
    masks = structured_prune(params, [("filter", 0.2), ("index", 0.2)],
                             prunable=lm_prunable, cfg=PruneConfig())
    params = apply_masks(params, masks)
    print(f"serving {cfg.name} at {sparsity_fraction(masks):.1%} sparsity")

    prefill_fn, decode_fn = adapter.serve_fns()
    engine = ServeEngine(params=params, cfg=cfg, prefill_fn=prefill_fn,
                         decode_fn=decode_fn, batch_slots=4, capacity=128,
                         temperature=args.temperature,   # <=0 → greedy
                         sample_seed=args.sample_seed,
                         masks=None if args.no_bsmm else masks)
    rng_np = np.random.RandomState(0)
    for i in range(args.requests):
        prompt = rng_np.randint(0, 200, size=rng_np.randint(4, 24))
        # mixed budgets: short and long requests share slots; the
        # scheduler refills a slot the moment its request finishes
        engine.submit(Request(uid=i, prompt=prompt.astype(np.int32),
                              max_new_tokens=max(2, (i % 3 + 1)
                                                 * args.max_new // 3)))
    done = engine.run()
    for r in sorted(done, key=lambda r: r.uid)[:6]:
        print(f"req {r.uid:02d}: prompt[{len(r.prompt):2d} toks] → "
              f"{r.tokens}")
    rep = engine.report
    mode = ("greedy" if args.temperature <= 0
            else f"T={args.temperature:.2f}")
    print(f"served {rep.requests} requests ({mode}) | "
          f"{rep.tokens_generated} tokens in {rep.decode_steps} decode "
          f"steps | occupancy {rep.slot_occupancy:.0%} | "
          f"{rep.tokens_per_s:.1f} tok/s")
    if rep.bsmm_enabled:
        print(f"bsmm decode: {rep.routed_matmuls} projections routed, "
              f"{rep.live_tiles}/{rep.total_tiles} tiles live "
              f"({rep.skipped_tile_fraction:.0%} skipped)")
    else:
        print("bsmm decode: off (dense)")


if __name__ == "__main__":
    main()
