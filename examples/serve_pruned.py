"""Batched serving demo: ServeEngine over a pruned (ticket) LM.

    PYTHONPATH=src python examples/serve_pruned.py [--arch yi-6b] \
        [--temperature 0.8]

Builds a reduced config of the chosen architecture, prunes it
crossbar-aware through ``repro.api.structured_prune``, and serves a
queue of batched requests through prefill + decode with KV caches —
greedy by default, temperature sampling with ``--temperature``.
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import numpy as np

from repro.api import LMAdapter, structured_prune
from repro.configs import PruneConfig, get_arch, scaled_down
from repro.core.masks import apply_masks, lm_prunable, sparsity_fraction
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 = temperature sampling")
    ap.add_argument("--sample-seed", type=int, default=0)
    args = ap.parse_args()

    cfg = scaled_down(get_arch(args.arch), dtype="float32")
    adapter = LMAdapter(cfg)
    params = adapter.init_params(jax.random.PRNGKey(0))

    # prune the serving weights (tile/crossbar-aware)
    masks = structured_prune(params, [("filter", 0.2), ("index", 0.2)],
                             prunable=lm_prunable, cfg=PruneConfig())
    params = apply_masks(params, masks)
    print(f"serving {cfg.name} at {sparsity_fraction(masks):.1%} sparsity")

    prefill_fn, decode_fn = adapter.serve_fns()
    engine = ServeEngine(params=params, cfg=cfg, prefill_fn=prefill_fn,
                         decode_fn=decode_fn, batch_slots=4, capacity=128,
                         temperature=args.temperature,   # <=0 → greedy
                         sample_seed=args.sample_seed)
    rng_np = np.random.RandomState(0)
    for i in range(args.requests):
        prompt = rng_np.randint(0, 200, size=rng_np.randint(4, 24))
        engine.submit(Request(uid=i, prompt=prompt.astype(np.int32),
                              max_new_tokens=args.max_new))
    done = engine.run()
    for r in sorted(done, key=lambda r: r.uid)[:6]:
        print(f"req {r.uid:02d}: prompt[{len(r.prompt):2d} toks] → "
              f"{r.tokens}")
    mode = ("greedy" if args.temperature <= 0
            else f"T={args.temperature:.2f}")
    print(f"served {len(done)} requests in batches of ≤4 ({mode})")


if __name__ == "__main__":
    main()
