"""Quickstart: ReaLPrune a small CNN and inspect the hardware savings.

    PYTHONPATH=src python examples/quickstart.py

Runs the full Algorithm 1 loop (train → crossbar-aware prune → accuracy
gate → lottery rewind) through the ``repro.api`` session layer on a
small CNN with synthetic CIFAR-like data, then reports sparsity,
crossbar savings, ReRAM training speedup, and the TPU block-sparse
kernel's tile savings for the resulting masks.
"""
import sys
sys.path.insert(0, "src")

import jax
import numpy as np

from repro.api import CNNAdapter, PruningSession
from repro.configs import CNNConfig, ConvSpec, PruneConfig
from repro.core.hardware import cnn_activation_volumes
from repro.core.masks import path_str
from repro.core import perf_model as pm
from repro.data import SyntheticImages

CFG = CNNConfig(
    name="quickstart-cnn", family="cnn",
    convs=(ConvSpec(32, pool=True), ConvSpec(64, pool=True), ConvSpec(64)),
    fc=(), num_classes=10, image_size=16)


def show(e):
    print(f"  iter {e.iteration:2d} [{e.granularity:7s}] "
          f"sparsity {e.sparsity_before:.2f}→{e.sparsity_after:.2f} "
          f"acc {e.accuracy:.3f} {'keep' if e.accepted else 'undo'}")


def main():
    print("== ReaLPrune quickstart ==")
    adapter = CNNAdapter(CFG, data=SyntheticImages(image_size=16, noise=0.25),
                         steps=80, batch_size=64, lr=0.05, lr_decay=0.95,
                         decay_every=40, eval_batches=3)
    session = PruningSession(
        adapter, PruneConfig(prune_fraction=0.15, max_iters=12,
                             accuracy_tolerance=0.02),
        callbacks=[show])
    res = session.run()
    print(f"final sparsity: {res.sparsity:.3f}")

    rep = session.hardware_report(
        activation_volumes=cnn_activation_volumes(CFG))
    print(f"crossbar cell savings: {rep.cell_savings:.3f}  "
          f"crossbars: {rep.xbars_needed}/{rep.xbars_unpruned} "
          f"(-{rep.xbar_savings:.1%})  "
          f"activation savings: {rep.activation_savings:.3f}")

    vols = cnn_activation_volumes(CFG)
    unpruned = pm.conv_layer_perf(
        CFG, {l.path: l.stats.n_xbars for l in rep.layers}, vols,
        act_cells_per_xbar=session.geometry.cells)
    pruned = pm.conv_layer_perf(
        CFG, {l.path: l.stats.xbars_needed_packed for l in rep.layers}, vols,
        act_cells_per_xbar=session.geometry.cells)
    print(f"ReRAM iso-area training speedup: "
          f"{pm.iso_area_speedup(unpruned, pruned):.2f}x")

    # TPU view: tile savings consumed by the Pallas block-sparse kernel
    from repro.kernels.ops import tile_density
    for pth in ("convs/2/w",):
        leaf = None

        def grab(path, x):
            nonlocal leaf
            if x is not None and path_str(path) == pth:
                leaf = np.asarray(x)
            return x
        jax.tree_util.tree_map_with_path(grab, res.masks,
                                         is_leaf=lambda x: x is None)
        from repro.core.crossbar import conv_to_matrix
        dens = tile_density(conv_to_matrix(leaf),
                            session.geometry.rows, session.geometry.cols)
        print(f"bsmm tile density for {pth}: {dens:.2f} "
              f"(TPU compute saving {1 - dens:.1%})")


if __name__ == "__main__":
    main()
