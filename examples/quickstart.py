"""Quickstart: ReaLPrune a small CNN and inspect the hardware savings.

    PYTHONPATH=src python examples/quickstart.py

Runs the full Algorithm 1 loop (train → crossbar-aware prune → accuracy
gate → lottery rewind) on a small CNN with synthetic CIFAR-like data,
then reports sparsity, crossbar savings, ReRAM training speedup, and
the TPU block-sparse kernel's tile savings for the resulting masks.
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CNNConfig, ConvSpec, PruneConfig
from repro.core import algorithm as alg
from repro.core.hardware import analyze_masks, cnn_activation_volumes
from repro.core.masks import apply_masks, cnn_prunable, path_str
from repro.core import perf_model as pm
from repro.data import SyntheticImages
from repro.models import cnn as cnn_lib
from repro.optim import exponential_epoch_decay, masked, sgd

CFG = CNNConfig(
    name="quickstart-cnn", family="cnn",
    convs=(ConvSpec(32, pool=True), ConvSpec(64, pool=True), ConvSpec(64)),
    fc=(), num_classes=10, image_size=16)
DATA = SyntheticImages(image_size=16, noise=0.25)
CONV_PRED = lambda p: "convs" in p or "shortcuts" in p  # noqa: E731


def main():
    rng = jax.random.PRNGKey(0)
    params0, bn0 = cnn_lib.init_params(rng, CFG)
    holder = {"bn": bn0}

    def train_fn(params, masks, steps=80):
        opt = masked(sgd(exponential_epoch_decay(0.05, 0.95, 40)), masks)
        opt_state = opt.init(params)
        state, params = bn0, apply_masks(params, masks)

        @jax.jit
        def step(params, opt_state, state, batch):
            def lf(p):
                loss, (nst, _) = cnn_lib.loss_fn(p, state, CFG, batch, True)
                return loss, nst
            (loss, nst), g = jax.value_and_grad(lf, has_aux=True)(params)
            params, opt_state = opt.update(g, opt_state, params)
            return params, opt_state, nst, loss

        for i in range(steps):
            b = DATA.batch(i, 64)
            params, opt_state, state, loss = step(
                params, opt_state, state,
                {"images": jnp.asarray(b["images"]),
                 "labels": jnp.asarray(b["labels"])})
        holder["bn"] = state
        return params

    def eval_fn(params, masks):
        accs = [float(cnn_lib.accuracy(
            params, holder["bn"], CFG,
            jnp.asarray(DATA.batch(10_000 + i, 128)["images"]),
            jnp.asarray(DATA.batch(10_000 + i, 128)["labels"])))
            for i in range(3)]
        return float(np.mean(accs))

    print("== ReaLPrune quickstart ==")
    res = alg.realprune(
        init_params=params0, train_fn=train_fn, eval_fn=eval_fn,
        prunable=cnn_prunable, conv_pred=CONV_PRED,
        cfg=PruneConfig(prune_fraction=0.15, max_iters=12,
                        accuracy_tolerance=0.02))
    for e in res.history:
        print(f"  iter {e.iteration:2d} [{e.granularity:7s}] "
              f"sparsity {e.sparsity_before:.2f}→{e.sparsity_after:.2f} "
              f"acc {e.accuracy:.3f} {'keep' if e.accepted else 'undo'}")
    print(f"final sparsity: {res.sparsity:.3f}")

    rep = analyze_masks(res.masks, CONV_PRED,
                        activation_volumes=cnn_activation_volumes(CFG))
    print(f"crossbar cell savings: {rep.cell_savings:.3f}  "
          f"crossbars: {rep.xbars_needed}/{rep.xbars_unpruned} "
          f"(-{rep.xbar_savings:.1%})  "
          f"activation savings: {rep.activation_savings:.3f}")

    vols = cnn_activation_volumes(CFG)
    unpruned = pm.conv_layer_perf(
        CFG, {l.path: l.stats.n_xbars for l in rep.layers}, vols)
    pruned = pm.conv_layer_perf(
        CFG, {l.path: l.stats.xbars_needed_packed for l in rep.layers}, vols)
    print(f"ReRAM iso-area training speedup: "
          f"{pm.iso_area_speedup(unpruned, pruned):.2f}x")

    # TPU view: tile savings consumed by the Pallas block-sparse kernel
    from repro.kernels.ops import tile_density
    for pth in ("convs/2/w",):
        leaf = None

        def grab(path, x):
            nonlocal leaf
            if x is not None and path_str(path) == pth:
                leaf = np.asarray(x)
            return x
        jax.tree_util.tree_map_with_path(grab, res.masks,
                                         is_leaf=lambda x: x is None)
        from repro.core.crossbar import conv_to_matrix
        dens = tile_density(conv_to_matrix(leaf), 128, 128)
        print(f"bsmm tile density for {pth}: {dens:.2f} "
              f"(TPU compute saving {1 - dens:.1%})")


if __name__ == "__main__":
    main()
