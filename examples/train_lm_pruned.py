"""End-to-end driver: train a ~100M-parameter LM for a few hundred
steps with the full production stack — Trainer (checkpoint/resume/
straggler policy), sharded-ready model code, masked optimizer — then
apply crossbar-aware (tile) pruning and continue training the ticket.

    PYTHONPATH=src python examples/train_lm_pruned.py \
        [--steps 200] [--prune-steps 100] [--ckpt /tmp/lm_ckpt]

The model is the xlstm-125m architecture scaled to ~100M params with a
small vocab (CPU-friendly); the same script runs any --arch.
"""
import argparse
import dataclasses
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, scaled_down
from repro.core import algorithm as alg
from repro.core.masks import (apply_masks, lm_prunable, make_masks,
                              sparsity_fraction)
from repro.data import DataPipeline, SyntheticLM
from repro.models import transformer as tfm
from repro.optim import adamw, constant, masked, warmup_cosine
from repro.train import Trainer


def build(arch: str):
    base = get_arch(arch)
    # ~100M params: d_model 1024, 12 layers, vocab 8192
    cfg = scaled_down(base, d_model=1024, n_layers=min(base.n_layers, 12),
                      n_heads=8, n_kv_heads=min(base.n_kv_heads, 4) or 4,
                      d_ff=3072 if base.d_ff else 0, head_dim=128,
                      vocab_size=8192, rnn_width=2048 if base.rnn_width
                      else None, dtype="float32")
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--prune-steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/lm_pruned_ckpt")
    args = ap.parse_args()

    cfg = build(args.arch)
    rng = jax.random.PRNGKey(0)
    params = tfm.init_params(rng, cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"== {cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps @ B={args.batch} S={args.seq} ==")

    gen = SyntheticLM(vocab_size=256, seq_len=args.seq, seed=0)

    def batch_fn(step):
        b = gen.batch(step, args.batch)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    def loss_fn(params, batch):
        loss, metrics = tfm.loss_fn(params, cfg, batch)
        return loss, metrics

    opt = adamw(warmup_cosine(3e-4, 20, args.steps))
    trainer = Trainer(loss_fn=loss_fn, optimizer=opt, params=params,
                      data_iter=DataPipeline(batch_fn, prefetch=0),
                      ckpt_dir=args.ckpt, ckpt_every=50, async_ckpt=True,
                      step_deadline_s=30.0)
    m0 = trainer.run(args.steps, log_every=25)
    print(f"dense phase done: loss {m0['loss']:.4f} "
          f"(resumable checkpoints in {args.ckpt})")

    # ---- crossbar-aware pruning of the trained LM ----
    trained = trainer.state.params
    masks = make_masks(trained, lm_prunable)
    for gran, frac in (("filter", 0.2), ("channel", 0.2), ("index", 0.2)):
        masks = alg.prune_step(trained, masks, gran, frac, lambda p: False)
    print(f"tile-pruned to sparsity {sparsity_fraction(masks):.1%} "
          f"(filter→channel→index, crossbar-aware)")

    # lottery rewind to the dense-phase start, retrain the ticket
    pruned = apply_masks(trained, masks)
    opt2 = masked(adamw(constant(1e-4)), masks)
    trainer2 = Trainer(loss_fn=loss_fn, optimizer=opt2, params=pruned,
                       data_iter=DataPipeline(batch_fn,
                                              start_step=args.steps,
                                              prefetch=0),
                       ckpt_dir=None)
    m1 = trainer2.run(args.prune_steps, log_every=20)
    print(f"pruned fine-tune: loss {m1['loss']:.4f} "
          f"(dense was {m0['loss']:.4f})")

    # hardware view of the pruned LM
    from repro.core.hardware import analyze_masks
    rep = analyze_masks(masks, lambda p: False)
    print(f"crossbars: {rep.xbars_needed}/{rep.xbars_unpruned} "
          f"(-{rep.xbar_savings:.1%}); cell savings {rep.cell_savings:.1%}")


if __name__ == "__main__":
    main()
