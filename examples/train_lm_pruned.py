"""End-to-end driver: train a ~100M-parameter LM for a few hundred
steps with the full production stack — a registry-built adapter
(``repro.api.make_adapter``) over ``Trainer`` (checkpoint/resume/
straggler policy), sharded-ready model code, masked optimizer — then
apply crossbar-aware (tile) pruning via ``repro.api.structured_prune``
and continue training the ticket.

    PYTHONPATH=src python examples/train_lm_pruned.py \
        [--steps 200] [--prune-steps 100] [--ckpt /tmp/lm_ckpt]

The model is the xlstm-125m architecture scaled to ~100M params with a
small vocab (CPU-friendly); the same script runs any --arch.  CLI
parity: ``python -m repro.api prune --arch xlstm-125m --scale tiny``.
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax

from repro.api import get_recipe, make_adapter, structured_prune
from repro.configs import PruneConfig, get_arch, scaled_down
from repro.core.hardware import analyze_masks
from repro.core.masks import apply_masks, sparsity_fraction
from repro.data import SyntheticLM


def build(arch: str):
    base = get_arch(arch)
    # ~100M params: d_model 1024, 12 layers, vocab 8192
    cfg = scaled_down(base, d_model=1024, n_layers=min(base.n_layers, 12),
                      n_heads=8, n_kv_heads=min(base.n_kv_heads, 4) or 4,
                      d_ff=3072 if base.d_ff else 0, head_dim=128,
                      vocab_size=8192, rnn_width=2048 if base.rnn_width
                      else None, dtype="float32")
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--prune-steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/lm_pruned_ckpt")
    args = ap.parse_args()

    cfg = build(args.arch)
    # make_adapter accepts a pre-scaled config instance: the family
    # registry still picks the adapter class and prunability data, so
    # this script needs no per-family branching (works for --arch
    # yi-6b, deepseek-v3-671b, recurrentgemma-2b, ...)
    adapter = make_adapter(cfg, data=SyntheticLM(vocab_size=256,
                                                 seq_len=args.seq, seed=0),
                           steps=args.steps, batch_size=args.batch,
                           peak_lr=3e-4, warmup=20, log_every=25,
                           step_deadline_s=30.0)
    params = adapter.init_params(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"== {cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps @ B={args.batch} S={args.seq} ==")

    trained = adapter.train(params, None, ckpt_dir=args.ckpt)
    print(f"dense phase done: loss {adapter.last_metrics['loss']:.4f} "
          f"(resumable checkpoints in {args.ckpt})")

    # ---- crossbar-aware pruning of the trained LM ----
    # the one-shot schedule is read off the registered "paper" recipe —
    # recipes are the single source of truth for prune programs, even
    # when (as here) the accuracy gate is skipped for a fixed schedule
    prune_cfg = PruneConfig()
    schedule = [(s.granularity, 0.2)
                for s in get_recipe("paper").stages if s.kind == "prune"]
    masks = structured_prune(trained, schedule,
                             prunable=adapter.prunable, cfg=prune_cfg)
    print(f"tile-pruned to sparsity {sparsity_fraction(masks):.1%} "
          f"({'→'.join(g for g, _ in schedule)}, crossbar-aware)")

    # lottery rewind to the dense-phase start, retrain the ticket
    pruned = apply_masks(trained, masks)
    adapter.train(pruned, masks, steps=args.prune_steps,
                  start_step=args.steps, learning_rate=1e-4)
    print(f"pruned fine-tune: loss {adapter.last_metrics['loss']:.4f}")

    # hardware view of the pruned LM at the config's crossbar geometry
    rep = analyze_masks(masks, adapter.conv_pred,
                        xbar_rows=prune_cfg.xbar_rows,
                        xbar_cols=prune_cfg.xbar_cols)
    print(f"crossbars: {rep.xbars_needed}/{rep.xbars_unpruned} "
          f"(-{rep.xbar_savings:.1%}); cell savings {rep.cell_savings:.1%}")


if __name__ == "__main__":
    main()
